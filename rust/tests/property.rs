//! Property-based tests over the whole stack, using the in-repo
//! mini-quickcheck harness (`util::quickcheck`). Each property runs against
//! randomized model dims / hardware specs / workloads, so these cover the
//! estimator and simulators far beyond the paper's single platform.

use std::sync::Arc;

use bestserve::config::{
    Architecture, ArrivalProcess, EfficiencyParams, HardwareConfig, LengthDist,
    ModelConfig, Phase, Platform, RequestClass, Scenario, Slo, Strategy, StrategySpace,
    Workload,
};
use bestserve::estimator::{AnalyticOracle, LatencyModel};
use bestserve::optimizer::{
    find_goodput, optimize_parallel, AnalyticFactory, GoodputConfig, PruneConfig,
};
use bestserve::planner::pareto::{dominates, frontier};
use bestserve::planner::{plan, LinearCardCost, PlanPoint, PlannerConfig};
use bestserve::simulator::{
    generate_workload, save_trace, simulate, MaterializedWorkload, SimParams,
};
use bestserve::testbed::{BlockManager, Engine, SeqInput, Testbed, TestbedConfig};
use bestserve::util::quickcheck::{check, Gen};
use bestserve::util::stats::{percentile, percentile_sorted};

/// A random but valid LLaMa-shaped model.
fn gen_model(g: &mut Gen) -> ModelConfig {
    let hq = *g.choose(&[8u64, 16, 32, 64]);
    let group = *g.choose(&[1u64, 2, 4, 8]);
    let hkv = (hq / group).max(1);
    let head = *g.choose(&[64u64, 128]);
    let h = hq * head;
    ModelConfig {
        name: "random".into(),
        hidden: h,
        intermediate: h * g.usize_in(2, 4) as u64,
        q_heads: hq,
        kv_heads: hkv,
        layers: g.usize_in(4, 80) as u64,
        dtype_bytes: 2,
    }
}

fn gen_platform(g: &mut Gen) -> Platform {
    let mut hw = HardwareConfig::ascend_910b3();
    hw.sc_flops = g.f64_in(50e12, 1000e12);
    hw.sm_bytes = g.f64_in(0.5e12, 4e12);
    hw.s_plus_bytes = g.f64_in(25e9, 900e9);
    Platform {
        model: gen_model(g),
        hardware: hw,
        eff: EfficiencyParams::paper_defaults(),
    }
}

#[test]
fn prop_estimator_monotone_in_batch_and_length() {
    check("estimator monotone", 60, |g| {
        let p = gen_platform(g);
        p.validate().map_err(|e| e.to_string())?;
        let tp = *g.choose(&[1u32, 2, 4, 8]);
        let o = AnalyticOracle::new(p, tp);
        let b = g.usize_in(1, 32) as u32;
        let s = g.usize_in(16, 8192) as u32;
        let pf = o.prefill_time(b, s);
        if !(pf > 0.0 && pf.is_finite()) {
            return Err(format!("prefill({b},{s}) = {pf}"));
        }
        if o.prefill_time(b + 1, s) < pf {
            return Err(format!("prefill not monotone in b at ({b},{s})"));
        }
        if o.prefill_time(b, s + 64) < pf {
            return Err(format!("prefill not monotone in s at ({b},{s})"));
        }
        let d = o.decode_step_time(b, s);
        if o.decode_step_time(b + 1, s) + 1e-15 < d {
            return Err(format!("decode not monotone in b at ({b},{s})"));
        }
        if o.decode_step_time(b, s + 64) + 1e-15 < d {
            return Err(format!("decode not monotone in ctx at ({b},{s})"));
        }
        Ok(())
    });
}

#[test]
fn prop_tp_never_slows_the_block_down_much() {
    // Sharding divides compute but adds comm; a higher tp must never make
    // PREFILL slower by more than the communication it introduces (for
    // small models the comm floor CAN dominate — a real TP overhead the
    // model is supposed to expose, so it is allowed for explicitly).
    check("tp prefill speedup", 40, |g| {
        let p = gen_platform(g);
        let b = g.usize_in(1, 8) as u32;
        let s = g.usize_in(256, 4096) as u32;
        let t1 = AnalyticOracle::new(p.clone(), 1).prefill_time(b, s);
        let comm_budget = {
            let eff = p.eff.prefill;
            let bw = b as f64 * s as f64 * p.model.hidden as f64 / 4.0
                / (eff.eplus * p.hardware.s_plus_bytes);
            p.model.layers as f64 * 2.0 * bw.max(p.hardware.comm_latency_floor)
        };
        let t4 = AnalyticOracle::new(p, 4).prefill_time(b, s);
        if t4 > t1 + comm_budget + 1e-9 {
            return Err(format!(
                "tp4 prefill {t4} vs tp1 {t1} + comm {comm_budget} at b={b} s={s}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_decode_span_heuristic_upper_bounds_exact() {
    // The paper heuristic prices every token at the FINAL context, so it
    // must upper-bound the exact growing-context sum.
    check("span heuristic bound", 40, |g| {
        let p = gen_platform(g);
        let o = AnalyticOracle::new(p, *g.choose(&[1u32, 2, 4]));
        let b = g.usize_in(1, 16) as u32;
        let s = g.usize_in(16, 4096) as u32;
        let s_plus = g.usize_in(1, 512) as u32;
        let h = o.decode_span(b, s, s_plus);
        let e = o.decode_span_exact(b, s, s_plus);
        if h + 1e-12 < e {
            return Err(format!("heuristic {h} < exact {e} at b={b} s={s} s+={s_plus}"));
        }
        Ok(())
    });
}

#[test]
fn prop_percentile_agrees_sorted_and_unsorted() {
    // `percentile` (clone + total_cmp sort) and `percentile_sorted` (the
    // hot path) must agree BIT FOR BIT on the same data for every q —
    // including out-of-range and NaN q, and single-sample inputs. Guards
    // the index-clamping fix: a NaN q used to saturate the position to 0
    // and silently return the minimum sample.
    check("percentile sorted/unsorted bit-identity", 200, |g| {
        let n = g.usize_in(1, 50);
        let xs: Vec<f64> = (0..n).map(|_| g.f64_in(-1e6, 1e6)).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(f64::total_cmp);
        let q = match g.u64_below(8) {
            0 => -5.0,
            1 => 0.0,
            2 => 50.0,
            3 => 100.0,
            4 => 105.0,
            5 => f64::NAN,
            6 => f64::INFINITY,
            _ => g.f64_in(0.0, 100.0),
        };
        let a = percentile(&xs, q);
        let b = percentile_sorted(&sorted, q);
        if a.to_bits() != b.to_bits() {
            return Err(format!("percentile({q}) {a} != percentile_sorted {b} on n={n}"));
        }
        if q.is_nan() {
            if !a.is_nan() {
                return Err(format!("NaN q must yield NaN, got {a}"));
            }
        } else {
            // In-range results interpolate order statistics, so they stay
            // within the sample's min/max envelope.
            let (lo, hi) = (sorted[0], sorted[n - 1]);
            if !(a >= lo && a <= hi) {
                return Err(format!("percentile({q}) = {a} outside [{lo}, {hi}]"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simulators_conserve_requests_and_order_time() {
    check("simulator conservation", 25, |g| {
        let p = Platform::paper_testbed();
        let o = Arc::new(AnalyticOracle::new(p.clone(), 4));
        let n = g.usize_in(50, 400);
        let w = Workload::poisson(&Scenario::fixed(
            "prop",
            g.usize_in(64, 2048) as u64,
            g.usize_in(4, 64) as u64,
            n,
        ));
        let rate = g.f64_in(0.2, 6.0);
        let strategy = if g.bool() {
            Strategy::collocation(g.usize_in(1, 3) as u32, 4)
        } else {
            Strategy::disaggregation(g.usize_in(1, 2) as u32, g.usize_in(1, 2) as u32, 4)
        };
        let params = SimParams { seed: g.u64_below(1 << 40), ..SimParams::default() };
        let rep = simulate(o.as_ref(), &p, &strategy, &w, rate, params)
            .map_err(|e| e.to_string())?;
        if rep.n != n {
            return Err(format!("lost requests: {} != {n}", rep.n));
        }
        if !rep.ttfts.iter().all(|x| x.is_finite() && *x > 0.0) {
            return Err("non-finite or non-positive TTFT".into());
        }
        if !rep.tpots.iter().all(|x| x.is_finite() && *x > 0.0) {
            return Err("non-finite or non-positive TPOT".into());
        }
        Ok(())
    });
}

#[test]
fn prop_testbed_conserves_and_respects_service_floor() {
    check("testbed conservation", 15, |g| {
        let p = Platform::paper_testbed();
        let o = AnalyticOracle::new(p.clone(), 4);
        let n = g.usize_in(40, 150);
        let s = g.usize_in(64, 1024) as u64;
        let s_plus = g.usize_in(4, 32) as u64;
        let w = Workload::poisson(&Scenario::fixed("prop", s, s_plus, n));
        let strategy = if g.bool() {
            Strategy::collocation(g.usize_in(1, 2) as u32, 4)
        } else {
            Strategy::disaggregation(1, g.usize_in(1, 2) as u32, 4)
        };
        let reqs = generate_workload(&w, g.f64_in(0.2, 3.0), g.u64_below(1 << 40))
            .map_err(|e| e.to_string())?;
        let tb = Testbed::new(&o, &p, strategy, TestbedConfig::default());
        let rep = tb.run(&reqs).map_err(|e| e.to_string())?.report;
        if rep.n != n {
            return Err(format!("lost requests: {} != {n}", rep.n));
        }
        // TTFT can never beat a single-request prefill.
        let floor = o.prefill_time(1, s as u32);
        if rep.ttft.min + 1e-9 < floor {
            return Err(format!("TTFT {} beats service floor {floor}", rep.ttft.min));
        }
        Ok(())
    });
}

/// A random plan point: goodput may be zero (infeasible) and the point may
/// be memory-rejected, to exercise the frontier's exclusion rules.
fn gen_plan_point(g: &mut Gen) -> PlanPoint {
    let cards = g.usize_in(1, 32) as u32;
    let goodput = if g.u64_below(4) == 0 { 0.0 } else { g.f64_in(0.1, 20.0) };
    let cost_per_hour = cards as f64 * g.f64_in(0.5, 8.0);
    PlanPoint {
        hardware: format!("hw{}", g.u64_below(3)),
        strategy: Strategy::collocation(cards, 1),
        cards,
        goodput,
        normalized: goodput / cards as f64,
        memory_rejected: g.u64_below(8) == 0,
        cost_per_mtok: bestserve::planner::cost::per_million_tokens(
            cost_per_hour,
            goodput,
            g.f64_in(8.0, 256.0),
        ),
        cost_per_hour,
    }
}

#[test]
fn prop_pareto_frontier_no_dominated_survivor_and_idempotent() {
    check("pareto frontier", 150, |g| {
        let n = g.size(40);
        let mut pts: Vec<PlanPoint> = (0..n).map(|_| gen_plan_point(g)).collect();
        // Seed duplicates: identical objective vectors must both survive.
        if !pts.is_empty() && g.bool() {
            let dup = pts[g.usize_in(0, pts.len() - 1)].clone();
            pts.push(dup);
        }
        let f = frontier(&pts);
        for s in &f {
            if s.goodput <= 0.0 || s.memory_rejected {
                return Err(format!("excluded point survived: {s:?}"));
            }
            if let Some(q) =
                pts.iter().find(|q| !q.memory_rejected && dominates(q, s))
            {
                return Err(format!("dominated survivor {s:?} (dominated by {q:?})"));
            }
        }
        // Idempotence: pruning the frontier again must change nothing.
        let ff = frontier(&f);
        if ff != f {
            return Err(format!("frontier not idempotent: {} -> {}", f.len(), ff.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_block_manager_conserves_blocks() {
    // Random allocate/grow/release interleavings: the manager must never
    // go block-negative (free > total or used out of sync with the live
    // set) and must report allocation failures exactly when the request
    // exceeds the free pool.
    check("block manager conservation", 200, |g| {
        let block_size = *g.choose(&[1u32, 8, 16, 32]);
        let total = g.usize_in(1, 256) as u64;
        let mut m = BlockManager::new(block_size, total);
        let mut live: Vec<u32> = Vec::new();
        for _ in 0..g.usize_in(1, 60) {
            match g.u64_below(3) {
                0 => {
                    let t = g.usize_in(1, 4096) as u32;
                    let free_before = m.free_blocks();
                    let fits = m.blocks_for(t) <= free_before;
                    if m.allocate(t) != fits {
                        return Err(format!("allocate({t}) disagreed with can-fit"));
                    }
                    if fits {
                        live.push(t);
                    } else if m.free_blocks() != free_before {
                        return Err("failed allocation changed the free pool".into());
                    }
                }
                1 if !live.is_empty() => {
                    let i = g.usize_in(0, live.len() - 1);
                    let t = live[i];
                    let delta = g.usize_in(1, 64) as u32;
                    let extra = m.blocks_for(t + delta) - m.blocks_for(t);
                    let fits = extra <= m.free_blocks();
                    if m.grow(t, t + delta) != fits {
                        return Err(format!("grow({t}, {}) disagreed with can-fit", t + delta));
                    }
                    if fits {
                        live[i] = t + delta;
                    }
                }
                _ if !live.is_empty() => {
                    let t = live.swap_remove(g.usize_in(0, live.len() - 1));
                    m.release(t);
                }
                _ => {}
            }
            let used: u64 = live.iter().map(|&t| m.blocks_for(t)).sum();
            if m.used_blocks() != used {
                return Err(format!(
                    "accounting drift: used {} vs live set {}",
                    m.used_blocks(),
                    used
                ));
            }
            if m.free_blocks() > total {
                return Err(format!("free {} exceeds capacity {total}", m.free_blocks()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_recompute_preemption_restores_freed_blocks() {
    // Engine runs under tight KV: recompute preemption must give back
    // exactly what it evicts — after every sequence completes, the cache is
    // fully free again (any leak or double-release shows up here) and no
    // request is lost.
    struct TinyModel;
    impl LatencyModel for TinyModel {
        fn prefill_time(&self, _b: u32, _s: u32) -> f64 {
            0.01
        }
        fn decode_step_time(&self, _b: u32, _ctx: u32) -> f64 {
            0.001
        }
    }
    check("preemption restores blocks", 60, |g| {
        let total = g.usize_in(8, 16) as u64;
        let n = g.usize_in(2, 6);
        let mut t = 0.0f64;
        let inputs: Vec<SeqInput> = (0..n)
            .map(|req| {
                t += g.f64_in(0.0, 0.05);
                SeqInput {
                    req,
                    ready: t,
                    input_len: g.usize_in(16, 48) as u32,
                    gen_len: g.usize_in(8, 64) as u32,
                    needs_prefill: true,
                }
            })
            .collect();
        let model = TinyModel;
        let mut e = Engine {
            model: &model,
            bmax_prefill: g.usize_in(1, 4) as u32,
            bmax_decode: g.usize_in(2, 8) as u32,
            kv: BlockManager::new(16, total),
        };
        let (out, _stats) = e.run(&inputs);
        if out.len() != n {
            return Err(format!("lost sequences: {} of {n} completed", out.len()));
        }
        if e.kv.free_blocks() != e.kv.total_blocks {
            return Err(format!(
                "KV leak: {} of {} blocks free after all completions",
                e.kv.free_blocks(),
                e.kv.total_blocks
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_flex_testbed_conserves_requests() {
    // The flexible-role (Nf) testbed under random pools and loads: every
    // request completes once with finite, positive metrics — the same
    // contract as the static engines.
    check("flex testbed conservation", 10, |g| {
        let p = Platform::paper_testbed();
        let o = AnalyticOracle::new(p.clone(), 4);
        let n = g.usize_in(40, 120);
        let s = g.usize_in(64, 1024) as u64;
        let w = Workload::poisson(&Scenario::fixed("prop", s, g.usize_in(4, 32) as u64, n));
        let strategy = Strategy::dynamic(g.usize_in(1, 3) as u32, 4);
        let reqs = generate_workload(&w, g.f64_in(0.2, 3.0), g.u64_below(1 << 40))
            .map_err(|e| e.to_string())?;
        let tb = Testbed::new(&o, &p, strategy, TestbedConfig::default());
        let rep = tb.run(&reqs).map_err(|e| e.to_string())?.report;
        if rep.n != n {
            return Err(format!("lost requests: {} != {n}", rep.n));
        }
        if !rep.ttfts.iter().all(|x| x.is_finite() && *x > 0.0) {
            return Err("non-finite or non-positive TTFT".into());
        }
        if !rep.tpots.iter().all(|x| x.is_finite() && *x > 0.0) {
            return Err("non-finite or non-positive TPOT".into());
        }
        let floor = o.prefill_time(1, s as u32);
        if rep.ttft.min + 1e-9 < floor {
            return Err(format!("TTFT {} beats service floor {floor}", rep.ttft.min));
        }
        Ok(())
    });
}

#[test]
fn prop_goodput_monotone_in_slo_relaxation() {
    // Loosening both SLO budgets can never reduce goodput.
    check("goodput slo monotone", 8, |g| {
        let p = Platform::paper_testbed();
        let o = AnalyticOracle::new(p.clone(), 4);
        let w = Workload::poisson(&Scenario::fixed("prop", 1024, 32, 400));
        let strategy = if g.bool() {
            Strategy::collocation(2, 4)
        } else {
            Strategy::disaggregation(1, 1, 4)
        };
        let cfg = GoodputConfig { tolerance: 0.2, ..GoodputConfig::default() };
        let params = SimParams::default();
        let tight = Slo { ttft: 1.0, tpot: 0.05, ..Slo::paper_default() };
        let loose = Slo { ttft: 4.0, tpot: 0.2, ..Slo::paper_default() };
        let gt = find_goodput(&o, &p, &strategy, &w, &tight, params, &cfg)
            .map_err(|e| e.to_string())?;
        let gl = find_goodput(&o, &p, &strategy, &w, &loose, params, &cfg)
            .map_err(|e| e.to_string())?;
        if gl + 0.25 < gt {
            return Err(format!("loose SLO goodput {gl} < tight {gt} for {strategy}"));
        }
        Ok(())
    });
}

#[test]
fn prop_architecture_parse_display_roundtrip() {
    check("arch roundtrip", 200, |g| {
        let arch = match *g.choose(&[0u8, 1, 2]) {
            0 => Architecture::Collocation { m: g.usize_in(1, 99) as u32 },
            1 => Architecture::Disaggregation {
                p: g.usize_in(1, 99) as u32,
                d: g.usize_in(1, 99) as u32,
            },
            _ => Architecture::Dynamic { m: g.usize_in(1, 99) as u32 },
        };
        let s = arch.to_string();
        let back = Architecture::parse(&s).map_err(|e| e.to_string())?;
        if back != arch {
            return Err(format!("{arch:?} -> {s} -> {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_pruned_plan_equals_brute_force() {
    // The planner's exactness claim: a pruned sweep (analytic zero filter +
    // warm-started bisection + bound dominance) must reproduce the
    // brute-force sweep bit for bit — same Pareto frontier, same min-cost
    // plan per target, and a point list that is a bit-identical subsequence
    // of the brute one (dominance may only drop rows that provably decide
    // nothing, never reorder or alter them). Deterministic arrivals make
    // every feasibility probe reproducible; the randomized SLO drives grids
    // through feasible, analytically-zero, and memory-rejected mixes.
    check("plan prune equivalence", 5, |g| {
        let platform = Platform::paper_testbed();
        let profiles = vec![HardwareConfig::ascend_910b3(), HardwareConfig::h100_sxm()];
        let scenario = Scenario::fixed(
            "prop",
            g.usize_in(64, 512) as u64,
            g.usize_in(2, 24) as u64,
            g.usize_in(60, 100),
        );
        let workload =
            Workload { arrival: ArrivalProcess::Deterministic, ..Workload::poisson(&scenario) };
        let slo =
            Slo { ttft: g.f64_in(0.05, 2.0), tpot: g.f64_in(0.01, 0.1), ..Slo::paper_default() };
        let base = PlannerConfig {
            targets: vec![g.f64_in(0.2, 1.5), g.f64_in(1.5, 20.0)],
            space: StrategySpace {
                max_cards: g.usize_in(2, 4) as u32,
                tp_choices: if g.bool() { vec![1, 2] } else { vec![2] },
                ..StrategySpace::default()
            },
            goodput: GoodputConfig { tolerance: 0.3, ..GoodputConfig::default() },
            check_memory: g.bool(),
            ..PlannerConfig::default()
        };
        let run = |prune: PruneConfig| {
            plan(
                &platform.model,
                &platform.eff,
                &profiles,
                &workload,
                &slo,
                &LinearCardCost,
                &PlannerConfig { prune, ..base.clone() },
                3,
            )
            .map_err(|e| e.to_string())
        };
        let pruned = run(PruneConfig::default())?;
        let brute = run(PruneConfig::none())?;
        if pruned.frontier != brute.frontier {
            return Err(format!(
                "frontier diverged: pruned has {} points, brute {}",
                pruned.frontier.len(),
                brute.frontier.len()
            ));
        }
        if pruned.min_cost != brute.min_cost {
            return Err(format!(
                "min-cost plans diverged:\n  pruned {:?}\n  brute  {:?}",
                pruned.min_cost, brute.min_cost
            ));
        }
        let mut brute_iter = brute.points.iter();
        for p in &pruned.points {
            if !brute_iter.any(|q| q == p) {
                return Err(format!("pruned point not a brute-sweep subsequence entry: {p:?}"));
            }
        }
        let grid = profiles.len() * base.space.enumerate().len();
        for (name, rep) in [("pruned", &pruned), ("brute", &brute)] {
            if rep.points_probed + rep.points_pruned != grid {
                return Err(format!(
                    "{name} counters broken: {} probed + {} pruned != {grid} grid points",
                    rep.points_probed, rep.points_pruned
                ));
            }
        }
        if pruned.points_probed > brute.points_probed {
            return Err(format!(
                "pruning probed more points ({}) than brute force ({})",
                pruned.points_probed, brute.points_probed
            ));
        }
        Ok(())
    });
}

/// A random but valid request-length distribution, spanning all three
/// families so class/length draws of every shape hit the cache path.
fn gen_len_dist(g: &mut Gen) -> LengthDist {
    match g.u64_below(3) {
        0 => LengthDist::Fixed(g.usize_in(8, 2048) as u64),
        1 => {
            let lo = g.usize_in(8, 512) as u64;
            LengthDist::Uniform { lo, hi: lo + g.usize_in(0, 1024) as u64 }
        }
        _ => LengthDist::LogNormal {
            mu: g.f64_in(3.0, 7.0),
            sigma: g.f64_in(0.2, 1.2),
            cap: g.usize_in(64, 4096) as u64,
        },
    }
}

#[test]
fn prop_materialized_workload_matches_direct_generation() {
    // The tentpole exactness claim of the per-probe fast path: sampling a
    // workload skeleton once and stamping it out per probed scale
    // (`MaterializedWorkload::at_scale`) must reproduce direct
    // `generate_workload` *bit for bit* — every arrival timestamp, length,
    // and class — across all four arrival processes (Replay included),
    // single- and multi-class mixes, and random seeds/base rates/scales.
    let trace_path = std::env::temp_dir()
        .join(format!("bestserve_prop_replay_{}.csv", std::process::id()));
    let trace_src = Workload::poisson(&Scenario::fixed("trace", 256, 16, 60));
    let trace_reqs = generate_workload(&trace_src, 2.0, 7).unwrap();
    save_trace(&trace_reqs, &trace_path).unwrap();
    let replay_path = trace_path.to_str().unwrap().to_string();
    check("materialized workload bit-identity", 30, |g| {
        let arrival = match g.u64_below(4) {
            0 => ArrivalProcess::Poisson,
            1 => ArrivalProcess::Bursty { cv: g.f64_in(0.4, 3.0) },
            2 => ArrivalProcess::Deterministic,
            _ => ArrivalProcess::Replay { path: replay_path.clone() },
        };
        let classes: Vec<RequestClass> = (0..g.usize_in(1, 3))
            .map(|i| RequestClass {
                name: format!("c{i}"),
                weight: g.f64_in(0.1, 5.0),
                input_len: gen_len_dist(g),
                gen_len: gen_len_dist(g),
                slo: None,
            })
            .collect();
        let w = Workload {
            name: "prop".into(),
            arrival,
            classes,
            base_rate: g.f64_in(0.2, 4.0),
            n_requests: g.usize_in(20, 150),
        };
        w.validate().map_err(|e| e.to_string())?;
        let seed = g.u64_below(1 << 40);
        let mat = MaterializedWorkload::new(&w, seed).map_err(|e| e.to_string())?;
        for _ in 0..3 {
            let scale = g.f64_in(0.05, 40.0);
            let direct = generate_workload(&w, scale, seed).map_err(|e| e.to_string())?;
            let cached = mat.at_scale(scale).map_err(|e| e.to_string())?;
            if direct.len() != cached.len() {
                return Err(format!(
                    "length diverged at scale {scale}: {} vs {}",
                    direct.len(),
                    cached.len()
                ));
            }
            for (d, c) in direct.iter().zip(&cached) {
                if d.id != c.id
                    || d.input_len != c.input_len
                    || d.gen_len != c.gen_len
                    || d.class != c.class
                    || d.arrival.to_bits() != c.arrival.to_bits()
                {
                    return Err(format!(
                        "request diverged at scale {scale} ({:?}): {d:?} vs {c:?}",
                        w.arrival
                    ));
                }
            }
        }
        Ok(())
    });
    let _ = std::fs::remove_file(&trace_path);
}

/// A bursty two-class mix with moderate lengths: multi-class and
/// non-Poisson (so both cache layers see the interesting paths) while
/// staying comfortably feasible under the paper-default SLO at tp 4 —
/// the fast-path anchors must not be vacuous.
fn anchor_mix(n_requests: usize) -> Workload {
    Workload {
        name: "anchor-mix".into(),
        arrival: ArrivalProcess::Bursty { cv: 2.0 },
        classes: vec![
            RequestClass {
                name: "chat".into(),
                weight: 0.7,
                input_len: LengthDist::Uniform { lo: 128, hi: 1024 },
                gen_len: LengthDist::Uniform { lo: 16, hi: 128 },
                slo: None,
            },
            RequestClass {
                name: "batch".into(),
                weight: 0.3,
                input_len: LengthDist::Fixed(2048),
                gen_len: LengthDist::Fixed(64),
                slo: None,
            },
        ],
        base_rate: 1.0,
        n_requests,
    }
}

#[test]
fn fast_paths_preserve_optimizer_rankings_bit_for_bit() {
    // Acceptance anchor: the full optimizer sweep — bursty multi-class mix,
    // memory pre-filter on, serial and threaded — must produce bit-identical
    // rankings with the per-probe fast paths (workload cache + latency-model
    // front cache) enabled and disabled.
    let platform = Platform::paper_testbed();
    let factory = AnalyticFactory::new(platform.clone());
    let w = anchor_mix(250);
    let slo = Slo::paper_default();
    let space = StrategySpace {
        max_cards: 8,
        tp_choices: vec![4],
        ..StrategySpace::default()
    };
    let run = |fast: bool, threads: usize| {
        let params = SimParams { front_cache: fast, ..SimParams::default() };
        let cfg = GoodputConfig {
            tolerance: 0.25,
            workload_cache: fast,
            ..GoodputConfig::default()
        };
        optimize_parallel(
            &factory, &platform, &space, &w, &slo, params, &cfg, true, threads,
        )
        .unwrap()
    };
    let reference = run(true, 1);
    assert!(
        reference.ranked.iter().any(|r| r.goodput > 0.0),
        "anchor sweep is vacuous: every strategy scored zero"
    );
    for (fast, threads) in [(false, 1), (true, 4), (false, 4)] {
        let rep = run(fast, threads);
        assert_eq!(rep, reference, "diverged at fast={fast} threads={threads}");
    }
}

#[test]
fn fast_paths_preserve_goodput_with_repeats_bit_for_bit() {
    // The averaged (repeats > 1) bisection draws one skeleton per repeat
    // with the exact per-repeat seeds of the direct path; the cached and
    // direct goodputs must agree to the bit. A (loose) per-class SLO pulls
    // the per-class percentile accumulation into the averaged path too.
    let p = Platform::paper_testbed();
    let o = AnalyticOracle::new(p.clone(), 4);
    let mut w = anchor_mix(150);
    w.classes[0].slo = Some(Slo { ttft: 3.0, tpot: 0.15, ..Slo::paper_default() });
    let slo = Slo::paper_default();
    let strategy = Strategy::disaggregation(1, 1, 4);
    let run = |fast: bool| {
        let params = SimParams { front_cache: fast, ..SimParams::default() };
        let cfg = GoodputConfig {
            tolerance: 0.25,
            repeats: 3,
            workload_cache: fast,
            ..GoodputConfig::default()
        };
        find_goodput(&o, &p, &strategy, &w, &slo, params, &cfg).unwrap()
    };
    let on = run(true);
    let off = run(false);
    assert!(on > 0.0, "anchor bisection is vacuous: goodput zero");
    assert_eq!(on.to_bits(), off.to_bits(), "cached {on} vs direct {off}");
}

#[test]
fn fast_paths_preserve_plan_report_bit_for_bit() {
    // Acceptance anchor for the planner: frontier, min-cost plans, and the
    // rendered CSV must be byte-identical with the fast paths on and off.
    let platform = Platform::paper_testbed();
    let profiles = vec![HardwareConfig::ascend_910b3()];
    let w = anchor_mix(200);
    let slo = Slo::paper_default();
    let run = |fast: bool| {
        let cfg = PlannerConfig {
            targets: vec![0.5, 4.0],
            space: StrategySpace {
                max_cards: 8,
                tp_choices: vec![4],
                ..StrategySpace::default()
            },
            goodput: GoodputConfig {
                tolerance: 0.3,
                workload_cache: fast,
                ..GoodputConfig::default()
            },
            sim_params: SimParams { front_cache: fast, ..SimParams::default() },
            check_memory: true,
            ..PlannerConfig::default()
        };
        plan(&platform.model, &platform.eff, &profiles, &w, &slo, &LinearCardCost, &cfg, 2)
            .unwrap()
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(on.frontier, off.frontier);
    assert_eq!(on.min_cost, off.min_cost);
    assert_eq!(on.to_csv().render(), off.to_csv().render());
    assert_eq!(on, off);
}

#[test]
fn prop_phase_tables_positive_for_random_dims() {
    check("tables positive", 80, |g| {
        let p = gen_platform(g);
        let b = g.usize_in(1, 64) as u32;
        let s = g.usize_in(1, 16384) as u32;
        let tp = *g.choose(&[1u32, 2, 4, 8]);
        for phase in [Phase::Prefill, Phase::Decode] {
            for m in bestserve::estimator::BLOCK_SEQUENCE {
                let t = m.compute_time(&p, phase, b, s, tp);
                if !(t > 0.0 && t.is_finite()) {
                    return Err(format!("{} {:?} = {t}", m.name(), phase));
                }
            }
        }
        Ok(())
    });
}
