//! Regression test for front-cache observability hygiene.
//!
//! `estimator::front_cache_totals()` is process-global: it accumulates
//! across every simulation in the process, so a CLI command that printed
//! the raw totals used to report every earlier run too. The fix is
//! `obs::FrontCacheScope` delta semantics (each run reports only itself)
//! plus `front_cache_reset` for sequential callers that want absolute
//! numbers.
//!
//! This file deliberately holds a SINGLE test: the totals are process-wide
//! atomics, and cargo runs the tests *within* a binary on parallel
//! threads. One test in its own integration binary gets a whole process to
//! itself, so the absolute-value assertions below are race-free.

use bestserve::config::{Platform, Scenario, Strategy, Workload};
use bestserve::estimator::{front_cache_reset, front_cache_totals, LatencyModel};
use bestserve::obs::FrontCacheScope;
use bestserve::simulator::{simulate, SimParams};

struct Flat;

impl LatencyModel for Flat {
    fn prefill_time(&self, _b: u32, _s: u32) -> f64 {
        0.1
    }
    fn decode_step_time(&self, _b: u32, _ctx: u32) -> f64 {
        0.01
    }
}

fn run_once() {
    let workload = Workload::poisson(&Scenario::fixed("fc", 128, 8, 60));
    simulate(
        &Flat,
        &Platform::paper_testbed(),
        &Strategy::collocation(2, 1),
        &workload,
        2.0,
        SimParams::default(),
    )
    .unwrap();
}

#[test]
fn scope_reports_per_run_deltas_not_process_totals() {
    front_cache_reset();
    let zero = front_cache_totals();
    assert_eq!((zero.hits, zero.misses), (0, 0));

    // First run: the scope's delta is exactly what the run contributed.
    let scope = FrontCacheScope::begin();
    run_once();
    let first = scope.delta();
    assert!(
        first.hits + first.misses > 0,
        "front cache saw no traffic — is SimParams::front_cache still on by default?"
    );

    // Second identical run: its own scope sees the same delta even though
    // the process totals have doubled — the accumulation bug the scope
    // fixes. (The cache is per-simulator, so no state leaks across runs.)
    let scope2 = FrontCacheScope::begin();
    run_once();
    let second = scope2.delta();
    assert_eq!((second.hits, second.misses), (first.hits, first.misses));

    let totals = front_cache_totals();
    assert_eq!((totals.hits, totals.misses), (2 * first.hits, 2 * first.misses));

    // Reset restores a clean slate; an idle scope then reports zero.
    front_cache_reset();
    let idle = FrontCacheScope::begin().delta();
    assert_eq!((idle.hits, idle.misses), (0, 0));
}
