//! Integration: Tables 4 & 5 of the paper, on the real analytic oracle.
//!
//! Table 4 (1p1d, tp=4, bmax 4/16, λ=3.5, CodeLlama-34b on 910B3):
//!   P90 TTFT 3650 ms (SLO 1500 violated), P90 TPOT 44.8 ms (SLO 70 ok).
//! Table 5 (2m collocation, tp=4, bmax 4, λ=3.5):
//!   P90 TTFT 556 ms (ok), P90 TPOT 4360 ms (violated, catastrophically).
//!
//! We assert the qualitative structure — which SLO each architecture
//! violates and by roughly what order — rather than the paper's absolute
//! numbers (its tuned constants are unpublished; see DESIGN.md §6).

use bestserve::config::{Platform, Scenario, Slo, Strategy, Workload};
use bestserve::estimator::AnalyticOracle;
use bestserve::simulator::{simulate, SimParams};

fn params(seed: u64) -> SimParams {
    SimParams { seed, ..SimParams::default() }
}

/// Table 4's operating point: the paper simulates 10k requests of OP2-like
/// shape (s=2048, s+=64). 4k requests keeps the test fast with stable P90s.
fn workload() -> Workload {
    Workload::poisson(&Scenario::fixed("table4", 2048, 64, 4000))
}

#[test]
fn table4_disagg_1p1d_shape() {
    let platform = Platform::paper_testbed();
    let oracle = AnalyticOracle::new(platform.clone(), 4);
    let strategy = Strategy::disaggregation(1, 1, 4);
    let rep = simulate(&oracle, &platform, &strategy, &workload(), 3.5, params(42)).unwrap();
    let slo = Slo::paper_default();
    let ttft_ms = rep.ttft.p90 * 1e3;
    let tpot_ms = rep.tpot.p90 * 1e3;
    // TTFT: far beyond the 1500 ms SLO (paper: 3650 ms). A single prefill
    // instance at λ=3.5 is near saturation, so queueing explodes; accept
    // anything clearly in violation and of queue-blowup magnitude.
    assert!(
        ttft_ms > slo.ttft * 1e3,
        "1p1d TTFT should violate SLO: {ttft_ms} ms"
    );
    assert!(ttft_ms > 2000.0, "expected queue blow-up, got {ttft_ms} ms");
    // TPOT: holds the SLO up to Algorithm 9's relaxation (paper: 44.8 ms;
    // our reconstructed decode step is ~45% heavier than the paper's
    // unpublished constants, landing P90 at ~70 ms — still feasible under
    // the (1+τ)·70 = 77 ms check the Optimizer actually applies).
    assert!(
        tpot_ms < (1.0 + slo.relaxation) * slo.tpot * 1e3,
        "1p1d TPOT should pass the relaxed SLO check: {tpot_ms} ms"
    );
    assert!(tpot_ms > 20.0, "TPOT should be nontrivial: {tpot_ms} ms");
}

#[test]
fn table5_colloc_2m_shape() {
    let platform = Platform::paper_testbed();
    let oracle = AnalyticOracle::new(platform.clone(), 4);
    let mut strategy = Strategy::collocation(2, 4);
    strategy.bmax_decode = 4; // Table 5a: maximum batch size 4
    let rep = simulate(&oracle, &platform, &strategy, &workload(), 3.5, params(42)).unwrap();
    let ttft_ms = rep.ttft.p90 * 1e3;
    let tpot_ms = rep.tpot.p90 * 1e3;
    // TTFT: within SLO (paper: 556 ms) — prefill prioritization works.
    assert!(ttft_ms < 1500.0, "2m TTFT should hold SLO: {ttft_ms} ms");
    // TPOT: catastrophically violated (paper: 4360 ms) — decode starvation.
    assert!(tpot_ms > 70.0, "2m TPOT should violate SLO: {tpot_ms} ms");
    assert!(
        tpot_ms > 500.0,
        "expected decode starvation blow-up, got {tpot_ms} ms"
    );
}

#[test]
fn architectures_flip_which_slo_breaks() {
    // The headline contrast of §2.4 / Tables 4–5, in one assertion pair.
    let platform = Platform::paper_testbed();
    let oracle = AnalyticOracle::new(platform.clone(), 4);
    let w = workload();
    let disagg = simulate(
        &oracle,
        &platform,
        &Strategy::disaggregation(1, 1, 4),
        &w,
        3.5,
        params(7),
    )
    .unwrap();
    let mut colloc_st = Strategy::collocation(2, 4);
    colloc_st.bmax_decode = 4;
    let colloc = simulate(&oracle, &platform, &colloc_st, &w, 3.5, params(7)).unwrap();
    assert!(disagg.ttft.p90 > colloc.ttft.p90, "disagg queues prefill");
    assert!(colloc.tpot.p90 > disagg.tpot.p90, "colloc starves decode");
}
