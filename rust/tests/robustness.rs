//! Failure-injection and edge-case coverage: malformed configs, missing
//! artifacts, degenerate workloads, extreme parameters — the paths a
//! downstream user hits first.

use bestserve::config::{
    HardwareConfig, ModelConfig, Platform, Scenario, Slo, Strategy, StrategySpace, Workload,
};
use bestserve::estimator::{AnalyticOracle, LatencyModel};
use bestserve::runtime::{GridLatencyModel, GridManifest, PjrtExecutable};
use bestserve::simulator::{generate_workload, simulate, SimParams};
use bestserve::testbed::{KvCapacity, Testbed, TestbedConfig};
use bestserve::util::json::Json;

#[test]
fn missing_artifact_is_a_clean_error() {
    let Err(err) = PjrtExecutable::load("/nonexistent/path/model.hlo.txt") else {
        panic!("expected error");
    };
    let msg = err.to_string();
    assert!(msg.contains("make artifacts"), "actionable message, got: {msg}");
}

#[test]
fn missing_manifest_is_a_clean_error() {
    let err = GridManifest::load(std::path::Path::new("/nonexistent")).unwrap_err();
    assert!(err.to_string().contains("make artifacts"));
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = std::env::temp_dir().join("bestserve_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(GridManifest::load(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), r#"{"latency_grid": {}}"#).unwrap();
    assert!(GridManifest::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn artifact_layout_version_mismatch_rejected() {
    let dir = std::env::temp_dir().join("bestserve_layout_mismatch");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"latency_grid": {"file": "x.hlo.txt", "n_params": 7, "nb": 4, "ns": 4, "s_stride": 16}}"#,
    )
    .unwrap();
    let Err(e) = GridLatencyModel::from_artifacts(&dir, &Platform::paper_testbed(), 1)
    else {
        panic!("expected error");
    };
    let err = e.to_string();
    assert!(err.contains("rebuild artifacts"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn invalid_configs_rejected_with_messages() {
    // Model with incompatible heads.
    let j = Json::parse(
        r#"{"name":"bad","hidden":100,"intermediate":400,"q_heads":7,"kv_heads":3,"layers":2}"#,
    )
    .unwrap();
    assert!(ModelConfig::from_json(&j).is_err());
    // Hardware with zero bandwidth.
    let mut hw = HardwareConfig::a100_80g();
    hw.s_plus_bytes = -1.0;
    assert!(hw.validate().is_err());
    // SLO percentile out of range.
    let slo = Slo { percentile: 0.0, ..Slo::paper_default() };
    assert!(slo.validate().is_err());
    // Strategy notation garbage.
    for bad in ["", "3p", "pd4", "2m-tpx", "0p0d"] {
        assert!(Strategy::parse(bad).is_err(), "{bad}");
    }
}

#[test]
fn single_request_workload() {
    let p = Platform::paper_testbed();
    let o = AnalyticOracle::new(p.clone(), 4);
    let w = Workload::poisson(&Scenario::fixed("one", 512, 8, 1));
    for st in [Strategy::collocation(1, 4), Strategy::disaggregation(1, 1, 4)] {
        let rep = simulate(&o, &p, &st, &w, 0.5, SimParams::default()).unwrap();
        assert_eq!(rep.n, 1);
        assert!(rep.ttft.p90 > 0.0);
    }
}

#[test]
fn gen_len_one_requests() {
    // s+ = 1: decode span is a single token; nothing divides by zero.
    let p = Platform::paper_testbed();
    let o = AnalyticOracle::new(p.clone(), 4);
    let w = Workload::poisson(&Scenario::fixed("g1", 512, 1, 50));
    let rep = simulate(
        &o,
        &p,
        &Strategy::disaggregation(1, 1, 4),
        &w,
        1.0,
        SimParams::default(),
    )
    .unwrap();
    assert!(rep.tpots.iter().all(|x| x.is_finite()));
}

#[test]
fn extreme_overload_terminates() {
    // 100x beyond capacity must still terminate with finite numbers.
    let p = Platform::paper_testbed();
    let o = AnalyticOracle::new(p.clone(), 4);
    let w = Workload::poisson(&Scenario::fixed("flood", 2048, 32, 500));
    let rep = simulate(
        &o,
        &p,
        &Strategy::disaggregation(1, 1, 4),
        &w,
        500.0,
        SimParams::default(),
    )
    .unwrap();
    assert_eq!(rep.n, 500);
    assert!(rep.ttft.max.is_finite());
}

#[test]
fn tiny_kv_capacity_still_serves() {
    // KV capacity barely above one sequence: heavy preemption, but every
    // request completes.
    let p = Platform::paper_testbed();
    let o = AnalyticOracle::new(p.clone(), 4);
    let w = Workload::poisson(&Scenario::fixed("tinykv", 100, 50, 30));
    let reqs = generate_workload(&w, 1.0, 3).unwrap();
    let tb = Testbed::new(
        &o,
        &p,
        Strategy::collocation(1, 4),
        TestbedConfig {
            kv_capacity: KvCapacity::Blocks(20), // 320 tokens
            ..TestbedConfig::default()
        },
    );
    let out = tb.run(&reqs).unwrap();
    assert_eq!(out.report.n, 30);
}

#[test]
fn variable_length_scenario_end_to_end() {
    // The paper claims variable-length support; exercise it through both
    // simulator and testbed.
    use bestserve::config::LengthDist;
    let p = Platform::paper_testbed();
    let o = AnalyticOracle::new(p.clone(), 4);
    let w = Workload::poisson(&Scenario {
        name: "mixed".into(),
        input_len: LengthDist::LogNormal { mu: 6.5, sigma: 0.6, cap: 4096 },
        gen_len: LengthDist::Uniform { lo: 8, hi: 128 },
        n_requests: 300,
    });
    let st = Strategy::disaggregation(1, 1, 4);
    let rep = simulate(&o, &p, &st, &w, 1.0, SimParams::default()).unwrap();
    assert_eq!(rep.n, 300);
    let reqs = generate_workload(&w, 1.0, 9).unwrap();
    let tb = Testbed::new(&o, &p, st, TestbedConfig::default());
    assert_eq!(tb.run(&reqs).unwrap().report.n, 300);
}

#[test]
fn empty_strategy_space_yields_empty_report() {
    let space = StrategySpace {
        max_cards: 1,
        tp_choices: vec![8], // tp > budget: nothing admissible
        ..StrategySpace::default()
    };
    assert!(space.enumerate().is_empty());
}

#[test]
fn grid_model_clamps_out_of_range_queries() {
    // Queries beyond the surface must clamp, not panic.
    let g = GridLatencyModel::from_surfaces(
        2,
        4,
        16,
        vec![1.0; 8],
        vec![0.5; 8],
    );
    assert!(g.prefill_time(1000, 1_000_000) > 0.0);
    assert!(g.decode_step_time(0, 0) > 0.0);
    assert!(g.decode_span_exact(5, 100_000, 100_000) >= 0.0);
}
