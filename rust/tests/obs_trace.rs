//! Golden and property tests for the sim-time tracing plane.
//!
//! The golden test pins the Chrome `trace_event` export of a tiny
//! deterministic collocation run: deterministic arrivals land exactly on
//! the `k / rate` grid, every request contributes one event of each
//! lifecycle kind, and two identical runs serialize to byte-identical
//! JSON. The property test checks the invariants every architecture must
//! uphold: events come out sorted by sim time and request count is
//! conserved (every arrival eventually produces a `decode_end`).

use bestserve::config::{ArrivalProcess, Platform, Scenario, Strategy, Workload};
use bestserve::estimator::LatencyModel;
use bestserve::obs::{EventKind, TraceSink};
use bestserve::simulator::{simulate_traced, SimParams, SimReport};
use bestserve::util::json::Json;

/// Constant-time latency oracle: service times independent of batch shape,
/// so the traced timeline is trivially reproducible by hand.
struct Flat;

impl LatencyModel for Flat {
    fn prefill_time(&self, _b: u32, _s: u32) -> f64 {
        0.1
    }
    fn decode_step_time(&self, _b: u32, _ctx: u32) -> f64 {
        0.01
    }
}

fn traced_run(strategy: &Strategy, n: usize) -> (SimReport, TraceSink) {
    // Deterministic arrivals: request k arrives exactly at k / base_rate =
    // k seconds. One second apart vs ~0.14 s of service, so requests are
    // served in isolation — singleton batches, no preemption.
    let workload = Workload {
        arrival: ArrivalProcess::Deterministic,
        ..Workload::poisson(&Scenario::fixed("tiny", 64, 4, n))
    };
    let params = SimParams { sim_trace: true, ..SimParams::default() };
    let sink = TraceSink::new();
    let rep = simulate_traced(
        &Flat,
        &Platform::paper_testbed(),
        strategy,
        &workload,
        1.0,
        params,
        &sink,
    )
    .unwrap();
    (rep, sink)
}

#[test]
fn chrome_trace_golden_for_tiny_colloc_run() {
    let st = Strategy::collocation(1, 1);
    let (rep, sink) = traced_run(&st, 3);
    assert_eq!(rep.n, 3);
    // 5 lifecycle events per request + 1 batch_formed per singleton batch.
    assert_eq!(sink.len(), 18);

    let dump = sink.to_chrome_json().dump();
    // Byte-identical across identical runs — the determinism "golden file".
    let (_, again) = traced_run(&st, 3);
    assert_eq!(dump, again.to_chrome_json().dump());

    let parsed = Json::parse(&dump).unwrap();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), 18);
    let by = |name: &str| -> Vec<&Json> {
        events
            .iter()
            .filter(|e| e.get("name").unwrap().as_str() == Some(name))
            .collect()
    };

    // Arrivals are instants pinned to the deterministic k-second grid
    // (Chrome ts is microseconds).
    let arrivals = by("arrival");
    assert_eq!(arrivals.len(), 3);
    for (k, a) in arrivals.iter().enumerate() {
        assert_eq!(a.get("ph").unwrap().as_str(), Some("i"));
        let ts = a.get("ts").unwrap().as_f64().unwrap();
        assert!((ts - (k + 1) as f64 * 1e6).abs() < 0.5, "arrival ts {ts}");
    }

    // One event of each lifecycle kind per request; isolated requests
    // never preempt each other.
    for kind in ["batch_formed", "prefill", "prefill_end", "decode", "decode_end"] {
        assert_eq!(by(kind).len(), 3, "{kind}");
    }
    assert!(by("preemption").is_empty());

    // Prefill spans are complete events lasting the Flat batch time.
    for p in by("prefill") {
        assert_eq!(p.get("ph").unwrap().as_str(), Some("X"));
        let dur = p.get("dur").unwrap().as_f64().unwrap();
        assert!((dur - 0.1e6).abs() < 1.0, "prefill dur {dur}");
    }

    // Track layout: the single collocated instance is tid 0; instance-less
    // arrivals go on the overflow track (max instance + 1 = 1).
    for e in events {
        let tid = e.get("tid").unwrap().as_f64().unwrap();
        let expect = if e.get("name").unwrap().as_str() == Some("arrival") { 1.0 } else { 0.0 };
        assert_eq!(tid, expect);
        assert_eq!(e.get("pid").unwrap().as_f64(), Some(0.0));
    }
}

#[test]
fn trace_events_sorted_and_request_count_conserved() {
    let n = 24;
    for st in [
        Strategy::collocation(2, 1),
        Strategy::disaggregation(1, 1, 1),
        Strategy::dynamic(2, 1),
    ] {
        let (rep, sink) = traced_run(&st, n);
        assert_eq!(rep.n, n, "{st}");
        let events = sink.events();
        assert!(!events.is_empty(), "{st}");

        // events() yields a timeline sorted by sim time.
        for w in events.windows(2) {
            assert!(w[0].t <= w[1].t, "{st}: out of order at t={}", w[1].t);
        }

        // Conservation: each of the n requests arrives exactly once and
        // finishes decoding exactly once, and ids stay in range.
        let count = |k: EventKind| events.iter().filter(|e| e.kind == k).count();
        assert_eq!(count(EventKind::Arrival), n, "{st}");
        assert_eq!(count(EventKind::PrefillEnd), n, "{st}");
        assert_eq!(count(EventKind::DecodeEnd), n, "{st}");
        for e in &events {
            if let Some(r) = e.request {
                assert!((r as usize) < n, "{st}: request id {r}");
            }
            assert!(e.t.is_finite() && e.dur >= 0.0, "{st}");
        }
    }
}
