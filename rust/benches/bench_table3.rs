//! Regenerates **Table 3** — Estimator breakdown for CodeLlama-34b on
//! Ascend 910B3 (b=1, s=2048, t=4, ℓ=48) — and times the oracle.
//!
//! Paper reference: prefill total 265.123 ms, decode step 33.573 ms.
//! Run: `cargo bench --bench bench_table3`

use bestserve::util::walltime::stopwatch;

use bestserve::config::{Phase, Platform};
use bestserve::estimator::{AnalyticOracle, LatencyModel};
use bestserve::report::{results_dir, table3};

fn main() -> bestserve::Result<()> {
    let platform = Platform::paper_testbed();
    let oracle = AnalyticOracle::new(platform.clone(), 4);

    println!("=== Table 3a: prefill phase (b=1, s=2048, t=4, l=48) ===");
    let t3a = table3(&oracle, &platform, Phase::Prefill, 1, 2048, 4);
    print!("{}", t3a.to_table().render());
    println!("total {:.3} ms   (paper: 265.123 ms, delta {:+.1}%)\n",
        t3a.total_ms, (t3a.total_ms / 265.123 - 1.0) * 100.0);

    println!("=== Table 3b: decode phase (b=1, s=2048+63=2111, t=4, l=48) ===");
    let t3b = table3(&oracle, &platform, Phase::Decode, 1, 2111, 4);
    print!("{}", t3b.to_table().render());
    println!(
        "total {:.3} ms   (paper: 33.573 ms, delta {:+.1}% — the paper's printed \
         total omits its own dispatch/comm rows; see DESIGN.md *6)\n",
        t3b.total_ms,
        (t3b.total_ms / 33.573 - 1.0) * 100.0
    );

    let dir = results_dir();
    t3a.to_csv().save(dir.join("table3a_prefill.csv"))?;
    t3b.to_csv().save(dir.join("table3b_decode.csv"))?;
    println!("wrote {}/table3{{a,b}}_*.csv", dir.display());

    // --- micro-bench: oracle latency, cold vs cached ------------------------
    let fresh = AnalyticOracle::new(platform.clone(), 4);
    let n_cold = 2_000u32;
    let t0 = stopwatch();
    for b in 0..n_cold {
        // distinct args -> every call misses the cache
        std::hint::black_box(fresh.prefill_time(1 + (b % 64), 16 + b));
    }
    let cold = t0.elapsed().as_secs_f64() / n_cold as f64;
    let n_hot = 2_000_000u32;
    let t1 = stopwatch();
    for _ in 0..n_hot {
        std::hint::black_box(fresh.prefill_time(1, 2048));
    }
    let hot = t1.elapsed().as_secs_f64() / n_hot as f64;
    let stats = fresh.cache_stats();
    println!("\n[bench] oracle ESTIMATE_TIME: cold {:.2} us/call, cached {:.0} ns/call (hit rate {:.3})",
        cold * 1e6, hot * 1e9, stats.hit_rate());
    Ok(())
}
