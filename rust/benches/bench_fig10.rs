//! Regenerates **Figure 10** — variance of simulated P90 TTFT against the
//! number of simulated requests: (a) one-shot runs keep oscillating within
//! roughly ±5% even at large n; (b) averaging 3 runs shrinks the spread.
//! This oscillation is what motivates Algorithm 9's relaxation factor τ=0.1.
//!
//! Run: `cargo bench --bench bench_fig10`

use bestserve::util::walltime::stopwatch;

use bestserve::config::{Platform, Scenario, Strategy, Workload};
use bestserve::estimator::AnalyticOracle;
use bestserve::report::{results_dir, variance_study};
use bestserve::simulator::SimParams;

fn main() -> bestserve::Result<()> {
    let platform = Platform::paper_testbed();
    let oracle = AnalyticOracle::new(platform.clone(), 4);
    let strategy = Strategy::disaggregation(1, 1, 4);
    let workload = Workload::poisson(&Scenario::fixed("fig10", 2048, 64, 1 /* overridden */));
    let counts = [500usize, 1_000, 2_000, 4_000, 8_000, 16_000, 32_000];
    let seeds = 8;

    let t0 = stopwatch();
    let vs = variance_study(
        &oracle,
        &platform,
        &strategy,
        &workload,
        2.5, // below the blow-up knee (ours is ~3.0) so P90 is stable-ish
        &counts,
        seeds,
        SimParams::default(),
    )?;
    let wall = t0.elapsed().as_secs_f64();

    println!("=== Figure 10: P90 TTFT spread vs #requests ({} seeds) ===", seeds);
    print!("{}", vs.to_table().render());
    let s1 = vs.spreads(false);
    let s3 = vs.spreads(true);
    let last = counts.len() - 1;
    println!(
        "\none-shot spread at n={}: {:.1}% (paper Fig 10a: ±5% persists at large n)",
        counts[last],
        s1[last] * 100.0
    );
    println!(
        "avg-of-3 spread at n={}: {:.1}% (paper Fig 10b: visibly reduced)",
        counts[last],
        s3[last] * 100.0
    );
    let improved = (0..counts.len()).filter(|&i| s3[i] < s1[i]).count();
    println!("averaging reduced the spread at {}/{} request counts", improved, counts.len());

    let dir = results_dir();
    vs.to_csv().save(dir.join("fig10_variance.csv"))?;
    println!("wrote {}/fig10_variance.csv", dir.display());
    println!("\n[bench] {} simulations in {:.1}s",
        counts.len() * seeds * 4, wall);
    Ok(())
}
