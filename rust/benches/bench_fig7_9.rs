//! Regenerates **Figures 7 & 9** — P90 TTFT and P90 TPOT against request
//! arrival rate, for the Table-4 (1p1d) and Table-5 (2m) setups. The curves
//! show the knee where queueing blows past the SLO — the object the
//! Optimizer bisects along.
//!
//! Run: `cargo bench --bench bench_fig7_9`

use bestserve::util::walltime::stopwatch;

use bestserve::config::{Platform, Scenario, Strategy, Workload};
use bestserve::estimator::AnalyticOracle;
use bestserve::report::{rate_sweep, results_dir};
use bestserve::simulator::SimParams;

fn main() -> bestserve::Result<()> {
    let platform = Platform::paper_testbed();
    let oracle = AnalyticOracle::new(platform.clone(), 4);
    let workload = Workload::poisson(&Scenario::fixed("sweep", 2048, 64, 4_000));
    let params = SimParams::default();
    let rates: Vec<f64> = (1..=16).map(|i| i as f64 * 0.5).collect();
    let dir = results_dir();

    println!("=== Figure 7: P90 TTFT/TPOT vs arrival rate — 1p1d-tp4 ===");
    let t0 = stopwatch();
    let f7 = rate_sweep(
        &oracle,
        &platform,
        &Strategy::disaggregation(1, 1, 4),
        &workload,
        &rates,
        params,
    )?;
    print!("{}", f7.to_table().render());
    f7.to_csv().save(dir.join("fig7_disagg_sweep.csv"))?;

    println!("\n=== Figure 9: P90 TTFT/TPOT vs arrival rate — 2m-tp4 (bmax 4) ===");
    let mut colloc = Strategy::collocation(2, 4);
    colloc.bmax_decode = 4;
    let f9 = rate_sweep(&oracle, &platform, &colloc, &workload, &rates, params)?;
    print!("{}", f9.to_table().render());
    f9.to_csv().save(dir.join("fig9_colloc_sweep.csv"))?;

    // Knee positions: first rate where each metric crosses its SLO.
    let knee = |rates: &[f64], ys: &[f64], slo: f64| -> Option<f64> {
        rates.iter().zip(ys).find(|(_, &y)| y > slo).map(|(r, _)| *r)
    };
    println!(
        "\nSLO crossings — 1p1d: TTFT>{:.1}s at λ≈{:?}, TPOT>70ms at λ≈{:?}",
        1.5,
        knee(&f7.rates, &f7.ttft_p90, 1.5),
        knee(&f7.rates, &f7.tpot_p90, 0.07)
    );
    println!(
        "SLO crossings — 2m:   TTFT>{:.1}s at λ≈{:?}, TPOT>70ms at λ≈{:?}",
        1.5,
        knee(&f9.rates, &f9.ttft_p90, 1.5),
        knee(&f9.rates, &f9.tpot_p90, 0.07)
    );
    println!(
        "(paper shape: the 1p1d curve is TTFT-limited, the 2m curve TPOT-limited)"
    );
    println!(
        "wrote {}/fig7_disagg_sweep.csv, fig9_colloc_sweep.csv",
        dir.display()
    );
    println!("\n[bench] {} rates x 2 setups in {:.2}s", rates.len(), t0.elapsed().as_secs_f64());
    Ok(())
}
