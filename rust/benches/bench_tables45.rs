//! Regenerates **Tables 4 & 5** and **Figures 6 & 8** — the disaggregation
//! (1p1d) and collocation (2m) simulator outputs at λ=3.5 req/s with 10 000
//! requests of the Table-4 workload (s=2048, s+=64), CodeLlama-34b @ 910B3.
//!
//! Paper reference:
//!   Table 4 (1p1d, bmax 4/16): P90 TTFT 3650.319, P99 6004.805,
//!                              P90 TPOT 44.849 (SLO 1500/70).
//!   Table 5 (2m, bmax 4):      P90 TTFT 556.309, P99 1091.503,
//!                              P90 TPOT 4360.659, P99 4656.043.
//! Run: `cargo bench --bench bench_tables45`

use bestserve::util::walltime::stopwatch;

use bestserve::config::{Platform, Scenario, Slo, Strategy, Workload};
use bestserve::estimator::AnalyticOracle;
use bestserve::report::{results_dir, table_slo};
use bestserve::simulator::SimParams;

fn main() -> bestserve::Result<()> {
    let platform = Platform::paper_testbed();
    let oracle = AnalyticOracle::new(platform.clone(), 4);
    let workload = Workload::poisson(&Scenario::fixed("table4", 2048, 64, 10_000));
    let slo = Slo::paper_default();
    let params = SimParams::default();
    let dir = results_dir();

    println!("=== Table 4: 1p1d-tp4, bmax 4/16, lambda=3.5, n=10000 ===");
    let st4 = Strategy::disaggregation(1, 1, 4);
    let t0 = stopwatch();
    let t4 = table_slo(&oracle, &platform, &st4, &workload, 3.5, &slo, params)?;
    let dt4 = t0.elapsed().as_secs_f64();
    print!("{}", t4.to_table().render());
    println!("(paper: TTFT P90 3650.3 / P99 6004.8; TPOT P90 44.8 — same SLO verdicts)\n");

    println!("=== Table 5: 2m-tp4, bmax 4, lambda=3.5, n=10000 ===");
    let mut st5 = Strategy::collocation(2, 4);
    st5.bmax_decode = 4; // Table 5a: maximum batch size 4
    let t1 = stopwatch();
    let t5 = table_slo(&oracle, &platform, &st5, &workload, 3.5, &slo, params)?;
    let dt5 = t1.elapsed().as_secs_f64();
    print!("{}", t5.to_table().render());
    println!("(paper: TTFT P90 556.3; TPOT P90 4360.7 — same SLO verdicts)\n");

    println!("=== Figure 6: 1p1d service-metric distributions ===");
    println!("{}", t4.render_histograms(20, 40));
    println!("=== Figure 8: 2m service-metric distributions ===");
    println!("{}", t5.render_histograms(20, 40));

    t4.to_csv().save(dir.join("table4_disagg.csv"))?;
    t5.to_csv().save(dir.join("table5_colloc.csv"))?;
    t4.histograms_csv(40).save(dir.join("fig6_disagg_hist.csv"))?;
    t5.histograms_csv(40).save(dir.join("fig8_colloc_hist.csv"))?;
    println!("wrote {}/table{{4,5}}_*.csv and fig{{6,8}}_*_hist.csv", dir.display());
    println!("\n[bench] 10k-request simulation wall time: disagg {dt4:.3}s, colloc {dt5:.3}s");
    Ok(())
}
