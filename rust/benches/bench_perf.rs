//! Performance benchmarks (EXPERIMENTS.md §Perf) — the whole-stack numbers:
//! L3 oracle + simulator + testbed + optimizer throughput, and the PJRT
//! grid's build/query costs. Run after `make artifacts` for the PJRT rows.
//!
//! Run: `cargo bench --bench bench_perf`

use bestserve::util::walltime::stopwatch;

use bestserve::config::{
    ArrivalProcess, FailureProcess, HardwareConfig, Platform, Scenario, Slo, Strategy,
    StrategySpace, Workload,
};
use bestserve::estimator::{AnalyticOracle, LatencyModel};
use bestserve::obs::{FrontCacheScope, Profiler, TraceSink};
use bestserve::optimizer::{
    find_goodput, optimize, optimize_parallel, AnalyticFactory, GoodputConfig, PruneConfig,
};
use bestserve::planner::{plan, plan_with_profiler, LinearCardCost, PlannerConfig};
use bestserve::runtime::{default_artifacts_dir, GridLatencyModel};
use bestserve::simulator::{
    generate_workload, simulate, simulate_traced, SimParams, SimReport, SpanMode,
};
use bestserve::testbed::{Testbed, TestbedConfig};
use bestserve::util::json::Json;

fn time<F: FnMut()>(mut f: F) -> f64 {
    let t0 = stopwatch();
    f();
    t0.elapsed().as_secs_f64()
}

fn main() -> bestserve::Result<()> {
    let platform = Platform::paper_testbed();
    let oracle = AnalyticOracle::new(platform.clone(), 4);
    println!("=== bench_perf — whole-stack hot-path numbers ===\n");

    // --- L3: oracle ---------------------------------------------------------
    let n = 500_000u32;
    let dt = time(|| {
        for i in 0..n {
            std::hint::black_box(oracle.decode_step_time(1 + (i % 16), 2048));
        }
    });
    println!("oracle cached lookup      : {:>10.0} calls/s", n as f64 / dt);
    let fresh = AnalyticOracle::new(platform.clone(), 4);
    let n_cold = 20_000u32;
    let dt = time(|| {
        for i in 0..n_cold {
            std::hint::black_box(fresh.decode_step_time(1 + (i % 64), 16 + i));
        }
    });
    println!("oracle cold evaluation    : {:>10.0} calls/s", n_cold as f64 / dt);

    // --- PJRT grid ----------------------------------------------------------
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        let t0 = stopwatch();
        let grid = GridLatencyModel::from_artifacts(&dir, &platform, 4)?;
        println!("PJRT grid build (compile+exec+cumsum): {:>6.2} s", t0.elapsed().as_secs_f64());
        let n = 2_000_000u32;
        let dt = time(|| {
            for i in 0..n {
                std::hint::black_box(grid.decode_step_time(1 + (i % 64), 17 + (i % 16000)));
            }
        });
        println!("PJRT grid lookup          : {:>10.0} calls/s", n as f64 / dt);
        let dt = time(|| {
            for i in 0..n {
                std::hint::black_box(grid.decode_span_exact(1 + (i % 64), 256, 2048));
            }
        });
        println!("PJRT grid exact span O(1) : {:>10.0} calls/s", n as f64 / dt);
    } else {
        println!("PJRT grid: artifacts missing (run `make artifacts`) — skipped");
    }

    // --- Simulator ----------------------------------------------------------
    let workload = Workload::poisson(&Scenario::fixed("perf", 2048, 64, 20_000));
    let st = Strategy::disaggregation(1, 1, 4);
    let params = SimParams::default();
    let mut rep_n = 0usize;
    let sim_dt = time(|| {
        let r = simulate(&oracle, &platform, &st, &workload, 3.0, params).unwrap();
        rep_n = r.n;
    });
    let dt = sim_dt;
    println!(
        "disagg simulator          : {:>10.0} requests/s simulated ({} reqs in {:.3}s)",
        rep_n as f64 / dt,
        rep_n,
        dt
    );
    let mut colloc = Strategy::collocation(2, 4);
    colloc.bmax_decode = 4;
    let dt = time(|| {
        let r = simulate(&oracle, &platform, &colloc, &workload, 3.0, params).unwrap();
        rep_n = r.n;
    });
    println!(
        "colloc simulator          : {:>10.0} requests/s simulated",
        rep_n as f64 / dt
    );
    let dynamic = Strategy::dynamic(2, 4);
    let mut switches = 0u64;
    let dt = time(|| {
        let r = simulate(&oracle, &platform, &dynamic, &workload, 3.0, params).unwrap();
        rep_n = r.n;
        switches = r.role_occupancy.map(|o| o.switches).unwrap_or(0);
    });
    println!(
        "dynamic (Nf) simulator    : {:>10.0} requests/s simulated ({switches} role switches)",
        rep_n as f64 / dt
    );

    // --- Workload plane ------------------------------------------------------
    // Generation must be an unmeasurable fraction of a sweep: every
    // FEASIBLE(λ) call regenerates the workload, so a slow generator would
    // tax every bisection step. Time the worst case we ship (bursty
    // Gamma-renewal arrivals × 3-class mix) against one simulation of the
    // same size.
    let mix = Workload::example_mix(20_000);
    let gen_rounds = 20u64;
    let gen_dt = time(|| {
        for k in 0..gen_rounds {
            std::hint::black_box(generate_workload(&mix, 3.0, k).unwrap());
        }
    });
    let per_gen = gen_dt / gen_rounds as f64;
    println!(
        "workload generation       : {:>10.0} requests/s generated (bursty 3-class mix)",
        mix.n_requests as f64 * gen_rounds as f64 / gen_dt
    );
    println!(
        "  generation / simulation : {:.2}% of one same-size disagg simulation",
        100.0 * per_gen / sim_dt
    );
    assert!(
        per_gen < 0.25 * sim_dt,
        "workload generation ({per_gen:.3}s) should be a small fraction of simulation ({sim_dt:.3}s)"
    );

    // --- Per-probe fast path -------------------------------------------------
    // One Algorithm-8 goodput bisection on a preset-shaped workload
    // (2048/64 fixed lengths), exact span mode, with the output-preserving
    // per-probe fast paths — the materialized-workload cache and the
    // latency-model front cache — off vs on. Exact mode is the stress case:
    // without the front cache every decode-span query re-sums s_+ locked
    // oracle lookups, and every FEASIBLE(λ) probe regenerates the workload;
    // with the fast paths a warm span is one direct-mapped probe and a probe
    // stamps its requests out of the cached skeleton. Same bits either way.
    let probe_wl = Workload::poisson(&Scenario::fixed("perf", 2048, 64, 4_000));
    let probe_st = Strategy::disaggregation(1, 1, 4);
    let probe = |fast: bool| {
        let p = SimParams {
            span_mode: SpanMode::Exact,
            front_cache: fast,
            ..SimParams::default()
        };
        let cfg = GoodputConfig { workload_cache: fast, ..GoodputConfig::default() };
        find_goodput(&oracle, &platform, &probe_st, &probe_wl, &Slo::paper_default(), p, &cfg)
            .unwrap()
    };
    let mut g_off = 0.0;
    let dt_off = time(|| g_off = probe(false));
    let fc_scope = FrontCacheScope::begin();
    let mut g_on = 0.0;
    let dt_on = time(|| g_on = probe(true));
    let fc = fc_scope.delta();
    let probe_speedup = dt_off / dt_on;
    println!(
        "goodput probe fast path   : exact-span bisection {dt_off:.2}s off vs {dt_on:.2}s on \
         — speedup {probe_speedup:.2}x"
    );
    println!(
        "  front cache             : {:.1}% hit rate ({} hits, {} misses); \
         oracle memo {:.1}% hit rate",
        100.0 * fc.hit_rate(),
        fc.hits,
        fc.misses,
        100.0 * oracle.cache_stats().hit_rate()
    );
    assert_eq!(
        g_on.to_bits(),
        g_off.to_bits(),
        "fast paths must be output-preserving: {g_on} (on) vs {g_off} (off) req/s"
    );
    assert!(
        probe_speedup >= 3.0,
        "per-probe fast paths: expected >= 3x on exact-span probes, got {probe_speedup:.2}x \
         ({dt_off:.2}s off vs {dt_on:.2}s on)"
    );

    // --- Testbed -------------------------------------------------------------
    let tb_workload = Workload::poisson(&Scenario::fixed("perf", 2048, 64, 3_000));
    let reqs = generate_workload(&tb_workload, 2.0, 99).unwrap();
    let tokens: u64 = reqs.iter().map(|r| r.gen_len as u64).sum();
    let tb = Testbed::new(&oracle, &platform, st.clone(), TestbedConfig::default());
    let dt = time(|| {
        std::hint::black_box(tb.run(&reqs).unwrap());
    });
    println!(
        "token-level testbed       : {:>10.0} tokens/s simulated ({} tokens in {:.3}s)",
        tokens as f64 / dt,
        tokens,
        dt
    );

    // --- Flexible-pool (Nf) testbed -----------------------------------------
    // The iteration-granular role-flipping ground truth engine on the same
    // workload: role switches, KV hand-offs and all.
    let tb_flex = Testbed::new(
        &oracle,
        &platform,
        Strategy::dynamic(2, 4),
        TestbedConfig::default(),
    );
    let mut flex_switches = 0u64;
    let mut flex_handoffs = 0u64;
    let dt = time(|| {
        let out = tb_flex.run(&reqs).unwrap();
        flex_switches = out.report.role_occupancy.map(|o| o.switches).unwrap_or(0);
        flex_handoffs = out.kv_handoffs;
    });
    println!(
        "flex-pool (Nf) testbed    : {:>10.0} tokens/s simulated ({flex_switches} role \
         switches, {flex_handoffs} KV hand-offs)",
        tokens as f64 / dt
    );

    // --- Optimizer ------------------------------------------------------------
    let space = StrategySpace {
        max_cards: 8,
        tp_choices: vec![1, 2, 4, 8],
        ..StrategySpace::default()
    };
    let factory = AnalyticFactory::new(platform.clone());
    let mut n_strategies = 0usize;
    let sweep_wl = Workload::poisson(&Scenario::fixed("perf", 2048, 64, 2_000));
    let dt = time(|| {
        let r = optimize(
            &factory,
            &platform,
            &space,
            &sweep_wl,
            &Slo::paper_default(),
            params,
            &GoodputConfig::default(),
        )
        .unwrap();
        n_strategies = r.ranked.len();
    });
    println!(
        "optimizer full space      : {n_strategies} strategies in {dt:.2}s \
         (paper target: 'minutes on a single standard CPU')"
    );

    // --- Parallel strategy sweep --------------------------------------------
    // Serial vs multi-threaded `optimize` over the same space. The oracle
    // caches are warm from the run above, so the comparison isolates the
    // sweep itself (simulation work), not model construction.
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let sweep = |n_threads: usize| {
        optimize_parallel(
            &factory,
            &platform,
            &space,
            &sweep_wl,
            &Slo::paper_default(),
            params,
            &GoodputConfig::default(),
            false,
            n_threads,
        )
        .unwrap()
    };
    let mut serial_rep = None;
    let t_serial = time(|| serial_rep = Some(sweep(1)));
    let mut par_rep = None;
    let t_par = time(|| par_rep = Some(sweep(threads)));
    let speedup = t_serial / t_par;
    println!(
        "parallel sweep            : {threads} threads {t_par:.2}s vs serial {t_serial:.2}s \
         — speedup {speedup:.2}x"
    );
    assert_eq!(
        serial_rep.unwrap().ranked,
        par_rep.unwrap().ranked,
        "parallel sweep must be deterministic"
    );
    if threads >= 2 {
        assert!(
            speedup > 1.0,
            "expected >1x speedup on {threads} cores, got {speedup:.2}x \
             ({t_serial:.2}s serial vs {t_par:.2}s parallel)"
        );
    }

    // --- Capacity planner: pruned vs brute force ----------------------------
    // The inverse question (target rate → min-cost cluster) over the FULL
    // preset grid: every hardware preset × cluster sizes ≤ 8 cards × the
    // whole strategy space, on ONE thread. The planner's promise is the
    // paper's "minutes on a single standard CPU" — hold it to a hard budget,
    // and hold the pruned sweep (analytic zero filter + warm-started
    // bisection + bound dominance) to the brute-force answers bit for bit.
    // Deterministic arrivals keep every feasibility probe reproducible, so
    // the equivalence contract of `PruneConfig` applies end to end.
    let profiles = HardwareConfig::presets();
    let plan_wl = Workload {
        arrival: ArrivalProcess::Deterministic,
        ..Workload::poisson(&Scenario::fixed("perf", 2048, 64, 1_000))
    };
    let plan_cfg = PlannerConfig {
        targets: vec![2.0, 6.0],
        space: StrategySpace {
            max_cards: 8,
            tp_choices: vec![1, 2, 4, 8],
            ..StrategySpace::default()
        },
        goodput: GoodputConfig { tolerance: 0.2, ..GoodputConfig::default() },
        sim_params: params,
        check_memory: true,
        prune: PruneConfig::all(),
    };
    let run_plan = |cfg: &PlannerConfig, wl: &Workload, prune: PruneConfig| {
        plan(
            &platform.model,
            &platform.eff,
            &profiles,
            wl,
            &Slo::paper_default(),
            &LinearCardCost,
            &PlannerConfig { prune, ..cfg.clone() },
            1,
        )
        .unwrap()
    };
    let mut brute = None;
    let dt_brute = time(|| brute = Some(run_plan(&plan_cfg, &plan_wl, PruneConfig::none())));
    let brute = brute.unwrap();
    let mut pruned = None;
    let dt_pruned = time(|| pruned = Some(run_plan(&plan_cfg, &plan_wl, PruneConfig::all())));
    let pruned = pruned.unwrap();
    let small_grid = brute.points_probed + brute.points_pruned;
    println!(
        "capacity planner          : {small_grid} grid points ({} hw profiles) on one thread — \
         brute {dt_brute:.2}s ({} probed) vs pruned {dt_pruned:.2}s ({} probed), \
         speedup {:.2}x",
        profiles.len(),
        brute.points_probed,
        pruned.points_probed,
        dt_brute / dt_pruned
    );
    const PLAN_BUDGET_S: f64 = 120.0;
    // The pruned 10x grid gets a tighter budget than the brute sweep: the
    // per-probe fast paths (workload cache + front cache) cheapen every
    // surviving probe on top of the sweep-level cuts.
    const PLAN_PRUNED_BUDGET_S: f64 = 100.0;
    assert!(
        dt_brute < PLAN_BUDGET_S,
        "brute-force preset-grid plan sweep took {dt_brute:.1}s, budget {PLAN_BUDGET_S}s on one CPU"
    );
    assert_eq!(
        pruned.frontier, brute.frontier,
        "pruned sweep must reproduce the brute-force Pareto frontier bit for bit"
    );
    assert_eq!(
        pruned.min_cost, brute.min_cost,
        "pruned sweep must reproduce the brute-force min-cost plans bit for bit"
    );
    assert_eq!(
        pruned.points_probed + pruned.points_pruned,
        small_grid,
        "probed + pruned must cover the grid"
    );
    assert!(
        pruned.points_probed <= brute.points_probed,
        "pruning must never probe more points than brute force"
    );

    // --- Capacity planner: 10x-larger search space --------------------------
    // The tentpole claim: the pruned sweep covers a >=10x-larger grid inside
    // the SAME single-CPU budget the brute-force sweep is held to above.
    // Cluster sizes up to 32 cards quadratically inflate the disaggregation
    // split axis (2310 grid points vs 174); a lighter per-probe workload
    // (300 requests, coarser tolerance) keeps each point honest while the
    // zero filter, warm-started bisections and dominance skips carry the
    // grid growth.
    let big_wl = Workload {
        arrival: ArrivalProcess::Deterministic,
        ..Workload::poisson(&Scenario::fixed("perf", 2048, 64, 300))
    };
    let big_cfg = PlannerConfig {
        space: StrategySpace {
            max_cards: 32,
            tp_choices: vec![1, 2, 4, 8],
            ..StrategySpace::default()
        },
        goodput: GoodputConfig { tolerance: 0.4, ..GoodputConfig::default() },
        ..plan_cfg.clone()
    };
    let mut big = None;
    let dt_big = time(|| big = Some(run_plan(&big_cfg, &big_wl, PruneConfig::all())));
    let big = big.unwrap();
    let big_grid = big.points_probed + big.points_pruned;
    println!(
        "capacity planner (pruned) : {big_grid} grid points ({:.1}x the brute grid) in \
         {dt_big:.2}s on one thread — {} probed, {} pruned, frontier {}",
        big_grid as f64 / small_grid as f64,
        big.points_probed,
        big.points_pruned,
        big.frontier.len()
    );
    assert!(
        big_grid >= 10 * small_grid,
        "big sweep covers {big_grid} grid points, expected >= 10x the {small_grid}-point grid"
    );
    assert!(
        dt_big < PLAN_PRUNED_BUDGET_S,
        "pruned {big_grid}-point plan sweep took {dt_big:.1}s, budget {PLAN_PRUNED_BUDGET_S}s \
         on one CPU"
    );

    // --- Observability plane -------------------------------------------------
    // The obs instruments are off by default and must cost essentially
    // nothing when off: `simulate_traced` with the `sim_trace` gate down is
    // one branch before delegating to the untraced path. Interleaved
    // min-of-rounds timing keeps the <2% bound robust to scheduler noise.
    let report_key = |r: &SimReport| {
        (
            r.n,
            r.ttft.p90.to_bits(),
            r.tpot.p90.to_bits(),
            r.e2e.p90.to_bits(),
            r.throughput.to_bits(),
            r.makespan.to_bits(),
        )
    };
    let obs_wl = Workload::poisson(&Scenario::fixed("perf", 2048, 64, 20_000));
    let off_sink = TraceSink::new();
    let mut dt_plain = f64::INFINITY;
    let mut dt_gated = f64::INFINITY;
    let mut rep_plain = None;
    let mut rep_gated = None;
    for _ in 0..3 {
        dt_plain = dt_plain.min(time(|| {
            rep_plain = Some(simulate(&oracle, &platform, &st, &obs_wl, 3.0, params).unwrap());
        }));
        dt_gated = dt_gated.min(time(|| {
            rep_gated = Some(
                simulate_traced(&oracle, &platform, &st, &obs_wl, 3.0, params, &off_sink)
                    .unwrap(),
            );
        }));
    }
    let (rep_plain, rep_gated) = (rep_plain.unwrap(), rep_gated.unwrap());
    let overhead = dt_gated / dt_plain - 1.0;
    println!(
        "disabled sim-trace hooks  : plain {dt_plain:.3}s vs gated {dt_gated:.3}s — \
         {:+.2}% overhead",
        100.0 * overhead
    );
    assert!(off_sink.is_empty(), "sim-trace gate down must record nothing");
    assert_eq!(
        report_key(&rep_plain),
        report_key(&rep_gated),
        "traced entry point with the gate down must reproduce the report bit for bit"
    );
    assert!(
        dt_gated <= dt_plain * 1.02 + 0.005,
        "disabled sim-trace hooks cost {:.2}% (> 2%): {dt_gated:.3}s gated vs \
         {dt_plain:.3}s plain",
        100.0 * overhead
    );

    // Gate up: same report bits, and the sink's export is valid Chrome
    // `trace_event` JSON (one entry per recorded event).
    let on_sink = TraceSink::new();
    let traced = SimParams { sim_trace: true, ..params };
    let rep_on =
        simulate_traced(&oracle, &platform, &st, &obs_wl, 3.0, traced, &on_sink).unwrap();
    assert_eq!(
        report_key(&rep_plain),
        report_key(&rep_on),
        "recording the sim trace must not change the report"
    );
    let chrome = Json::parse(&on_sink.to_chrome_json().dump())
        .expect("sim trace must serialize to valid JSON");
    let n_events = chrome.get("traceEvents").and_then(Json::as_arr).map(<[Json]>::len);
    assert_eq!(n_events, Some(on_sink.len()), "one trace entry per recorded event");
    println!(
        "  sim trace               : {} events, valid Chrome trace_event JSON",
        on_sink.len()
    );

    // Profiled planner sweep: identical PlanReport to the unprofiled pruned
    // run above, and the span trace is the `--profile` payload CI keeps as
    // an artifact (openable in Perfetto).
    let prof = Profiler::on();
    let mut prof_rep = None;
    let dt_prof = time(|| {
        prof_rep = Some(
            plan_with_profiler(
                &platform.model,
                &platform.eff,
                &profiles,
                &plan_wl,
                &Slo::paper_default(),
                &LinearCardCost,
                &plan_cfg,
                1,
                &prof,
            )
            .unwrap(),
        );
    });
    let prof_rep = prof_rep.unwrap();
    assert_eq!(
        prof_rep.frontier, pruned.frontier,
        "profiling must not change the Pareto frontier"
    );
    assert_eq!(
        prof_rep.min_cost, pruned.min_cost,
        "profiling must not change the min-cost plans"
    );
    let spans = prof.spans();
    assert!(!spans.is_empty(), "a profiled sweep must record spans");
    Json::parse(&prof.to_chrome_json().dump())
        .expect("sweep profile must serialize to valid JSON");
    let profile_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("bench manifest dir sits below the workspace root")
        .join("target")
        .join("bench_perf_profile.json");
    prof.write_json(&profile_path)?;
    println!(
        "sweep profiler            : {} spans over a {dt_prof:.2}s profiled plan — wrote {}",
        spans.len(),
        profile_path.display()
    );

    // --- Failure plane -------------------------------------------------------
    // The churn gate (`SimParams::failures`) is off by default and the off
    // path must stay free: no plane is built, no RNG is drawn, and the
    // report is bit-identical to a run that never configured the feature —
    // even when an (unread) outage process is set. Same interleaved
    // min-of-rounds discipline as the obs case above.
    let churn_off = SimParams {
        failures: false,
        failure: FailureProcess { mtbf: 30.0, mttr: 1.0 },
        ..params
    };
    let mut dt_base = f64::INFINITY;
    let mut dt_off = f64::INFINITY;
    let mut rep_base = None;
    let mut rep_off = None;
    for _ in 0..3 {
        dt_base = dt_base.min(time(|| {
            rep_base = Some(simulate(&oracle, &platform, &st, &obs_wl, 3.0, params).unwrap());
        }));
        dt_off = dt_off.min(time(|| {
            rep_off = Some(simulate(&oracle, &platform, &st, &obs_wl, 3.0, churn_off).unwrap());
        }));
    }
    let (rep_base, rep_off) = (rep_base.unwrap(), rep_off.unwrap());
    let churn_overhead = dt_off / dt_base - 1.0;
    println!(
        "disabled failure plane    : base {dt_base:.3}s vs gate-off {dt_off:.3}s — \
         {:+.2}% overhead",
        100.0 * churn_overhead
    );
    assert!(rep_off.churn.is_none(), "failure gate down must report no churn");
    assert_eq!(
        report_key(&rep_base),
        report_key(&rep_off),
        "the failure gate down must reproduce the report bit for bit"
    );
    assert!(
        dt_off <= dt_base * 1.02 + 0.005,
        "disabled failure plane costs {:.2}% (> 2%): {dt_off:.3}s gate-off vs \
         {dt_base:.3}s base",
        100.0 * churn_overhead
    );

    // Gate up on the same run: the plane injects outages and the report
    // carries the tallies. Not a perf assertion — churn legitimately slows
    // and reshapes the run.
    let churn_on = SimParams { failures: true, ..churn_off };
    let rep_churn = simulate(&oracle, &platform, &st, &obs_wl, 3.0, churn_on).unwrap();
    let churn = rep_churn.churn.expect("failure gate up must report churn");
    assert!(churn.failures > 0, "a 30 s MTBF over this makespan must fail at least once");
    assert!(churn.failures >= churn.recoveries, "recoveries cannot outnumber failures");
    println!(
        "  enabled churn           : {} failures, {} recoveries, {} lost-KV re-prefills, \
         {:.1} s downtime",
        churn.failures, churn.recoveries, churn.lost_kv_reprefills, churn.downtime
    );
    Ok(())
}
