//! Ablations over the design choices the paper discusses but does not
//! sweep: the pseudo-batch scalar τ (eq. 9), the decode-span pricing mode
//! (request-level heuristic vs token-level exact), the SLO relaxation
//! factor τ_slo (Algorithm 9 / Figure 10 discussion), and the
//! disaggregation KV-transfer cost.
//!
//! Run: `cargo bench --bench bench_ablations`

use bestserve::util::walltime::stopwatch;

use bestserve::config::{Platform, Scenario, Slo, Strategy, Workload};
use bestserve::estimator::AnalyticOracle;
use bestserve::optimizer::{find_goodput, GoodputConfig};
use bestserve::simulator::{simulate, SimParams, SpanMode};
use bestserve::testbed::{testbed_goodput, GroundTruthConfig};
use bestserve::util::csv::Csv;
use bestserve::util::table::Table;

fn main() -> bestserve::Result<()> {
    let platform = Platform::paper_testbed();
    let oracle = AnalyticOracle::new(platform.clone(), 4);
    let slo = Slo::paper_default();
    let mut scenario = Scenario::op2();
    scenario.n_requests = 1000;
    let workload = Workload::poisson(&scenario);
    let strategy = Strategy::disaggregation(1, 1, 4);
    let cfg = GoodputConfig { tolerance: 0.05, ..GoodputConfig::default() };
    let t_start = stopwatch();
    let dir = bestserve::report::results_dir();

    // --- A1: pseudo-batch scalar τ ------------------------------------------
    println!("=== A1: pseudo-batch scalar τ (eq. 9) — 1p1d-tp4, OP2 ===");
    let truth = testbed_goodput(
        &oracle,
        &platform,
        &strategy,
        &workload,
        &slo,
        &GroundTruthConfig::default(),
        7,
    )?;
    let mut t = Table::new(&["tau", "predicted goodput", "rel err vs testbed"]).numeric_body();
    let mut csv = Csv::new(&["tau", "predicted", "truth", "rel_err"]);
    for tau in [1.0, 1.25, 1.5, 2.0, 2.5, 3.5, 5.0] {
        let params = SimParams { tau, ..SimParams::default() };
        let g = find_goodput(&oracle, &platform, &strategy, &workload, &slo, params, &cfg)?;
        let err = (g - truth) / truth;
        t.row(&[format!("{tau}"), format!("{g:.3}"), format!("{:+.1}%", err * 100.0)]);
        csv.row_f64(&[tau, g, truth, err]);
    }
    print!("{}", t.render());
    println!("testbed ground truth: {truth:.3} req/s");
    println!("(larger τ underprices decode interference -> goodput overestimated,");
    println!(" the §5 'over-simplification in decode phase' failure mode)\n");
    csv.save(dir.join("ablation_tau.csv"))?;

    // --- A2: decode span pricing --------------------------------------------
    println!("=== A2: decode-span pricing — request-level heuristic vs exact ===");
    for mode in [SpanMode::PaperHeuristic, SpanMode::Exact] {
        let params = SimParams { span_mode: mode, tau: 1.0, ..SimParams::default() };
        let t0 = stopwatch();
        let g = find_goodput(&oracle, &platform, &strategy, &workload, &slo, params, &cfg)?;
        println!(
            "  {:?}: goodput {:.3} req/s  (optimizer wall {:.2}s)",
            mode,
            g,
            t0.elapsed().as_secs_f64()
        );
    }
    println!("(the heuristic prices all tokens at the final context — a strict");
    println!(" upper bound on exact, so its goodput is a slight underestimate)\n");

    // --- A3: SLO relaxation factor τ_slo ------------------------------------
    println!("=== A3: Algorithm 9 relaxation factor τ_slo ===");
    let mut t = Table::new(&["tau_slo", "goodput"]).numeric_body();
    for relax in [0.0, 0.05, 0.1, 0.2] {
        let slo_r = Slo { relaxation: relax, ..slo };
        let params = SimParams { tau: 1.0, ..SimParams::default() };
        let g = find_goodput(&oracle, &platform, &strategy, &workload, &slo_r, params, &cfg)?;
        t.row(&[format!("{relax}"), format!("{g:.3}")]);
    }
    print!("{}", t.render());
    println!("(τ_slo=0 underestimates goodput — the Figure 10 variance argument)\n");

    // --- A4: disaggregation KV-transfer cost --------------------------------
    println!("=== A4: KV-cache transfer cost (disaggregation hand-off) ===");
    for (label, kv) in [("with transfer", true), ("without", false)] {
        let params = SimParams { tau: 1.0, kv_transfer: kv, ..SimParams::default() };
        let rep = simulate(&oracle, &platform, &strategy, &workload, 2.0, params)?;
        // TTFT/TPOT are transfer-invariant by definition (the shift moves
        // decode start and completion together); the end-to-end request
        // latency is where the hand-off cost lands.
        println!(
            "  {label:16}: P90 TTFT {:7.1} ms | P90 TPOT {:6.2} ms | mean e2e {:8.1} ms",
            rep.ttft.p90 * 1e3,
            rep.tpot.p90 * 1e3,
            rep.e2e.mean * 1e3
        );
    }
    println!("(TTFT/TPOT are invariant to the hand-off by construction; the ~15 ms");
    println!(" 2048-token KV move on 90 GB/s HCCS appears in end-to-end latency &");
    println!(" queueing only — matching the paper's 'additional communication");
    println!(" overhead' framing rather than an SLO-metric effect)");

    println!("\n[bench] ablations in {:.1}s; wrote {}/ablation_tau.csv",
        t_start.elapsed().as_secs_f64(), dir.display());
    Ok(())
}
