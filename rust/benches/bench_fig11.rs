//! Regenerates **Figure 11** — the headline validation: normalized goodput
//! of every strategy in the space, BestServe prediction vs ground truth,
//! across the four operating scenarios, with per-panel average |relative
//! error|.
//!
//! Paper reference errors (vs its real vLLM-Ascend cluster): OP1 11.2%,
//! OP2 12.1%, OP3 8.6%, OP4 30.1%. Our ground truth is the token-level
//! testbed (DESIGN.md §Hardware-Adaptation); the pseudo-batch scalar is
//! calibrated to τ=1.0 against it (the paper's §4.1 tuning protocol; its
//! 2.5 was tuned against its own cluster). A τ=2.5 ablation panel shows
//! the paper's qualitative finding — error explodes in generation-heavy
//! OP4 — survives the substitution.
//!
//! OP1 note: our reconstructed prefill(1, 8192) is 1.76 s > the 1.5 s TTFT
//! SLO, so the default-SLO OP1 panel is degenerate (predictor and testbed
//! both report zero goodput everywhere — trivial agreement). We report OP1
//! under a 3 s TTFT / 120 ms TPOT SLO to exercise the ranking, and say so.
//!
//! Run: `cargo bench --bench bench_fig11`

use bestserve::util::walltime::stopwatch;

use bestserve::config::{Platform, Scenario, Slo, StrategySpace, Workload};
use bestserve::optimizer::AnalyticFactory;
use bestserve::report::results_dir;
use bestserve::simulator::SimParams;
use bestserve::validation::{validate, ValidationConfig};

fn panel(
    platform: &Platform,
    scenario: &Scenario,
    slo: &Slo,
    tau: f64,
    n_requests: usize,
) -> bestserve::Result<bestserve::validation::ValidationReport> {
    let mut sc = scenario.clone();
    sc.n_requests = n_requests;
    let workload = Workload::poisson(&sc);
    let space = StrategySpace {
        max_cards: 8,
        tp_choices: vec![2, 4, 8],
        ..StrategySpace::default()
    };
    let mut cfg = ValidationConfig::default();
    cfg.sim_params = SimParams { tau, ..SimParams::default() };
    let factory = AnalyticFactory::new(platform.clone());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    validate(&factory, platform, &space, &workload, slo, &cfg, threads)
}

fn main() -> bestserve::Result<()> {
    let platform = Platform::paper_testbed();
    let slo = Slo::paper_default();
    let op1_slo = Slo { ttft: 3.0, tpot: 0.120, ..slo };
    let dir = results_dir();
    let t0 = stopwatch();

    let panels: Vec<(Scenario, Slo, usize, &str)> = vec![
        (Scenario::op1(), op1_slo, 500, "OP1 (SLO relaxed to 3s/120ms — see header)"),
        (Scenario::op2(), slo, 800, "OP2"),
        (Scenario::op3(), slo, 800, "OP3"),
        (Scenario::op4(), slo, 400, "OP4"),
    ];

    let mut errors = Vec::new();
    for (sc, panel_slo, n, label) in &panels {
        let rep = panel(&platform, sc, panel_slo, 1.0, *n)?;
        println!("=== Figure 11 panel: {label} (tau=1.0 calibrated) ===");
        print!("{}", rep.to_table().render());
        let err = rep.mean_abs_rel_error();
        println!(
            "average |relative error| = {:.1}%  |  recommendation quality = {:.2}\n",
            err * 100.0,
            rep.recommendation_quality()
        );
        rep.to_csv().save(dir.join(format!("fig11_{}.csv", sc.name)))?;
        errors.push((sc.name.clone(), err));
    }

    println!("=== tau ablation (paper default tau=2.5) ===");
    let mut tau_rows = Vec::new();
    for (sc, panel_slo, n, _) in &panels {
        let rep = panel(&platform, sc, panel_slo, 2.5, (*n).min(500))?;
        tau_rows.push((sc.name.clone(), rep.mean_abs_rel_error()));
    }
    println!("scenario | err(tau=1.0) | err(tau=2.5)   [paper err vs its cluster]");
    let paper = [("OP1", 11.2), ("OP2", 12.1), ("OP3", 8.6), ("OP4", 30.1)];
    for (i, (name, e1)) in errors.iter().enumerate() {
        println!(
            "  {name}   |   {:5.1}%     |   {:5.1}%        [{:.1}%]",
            e1 * 100.0,
            tau_rows[i].1 * 100.0,
            paper[i].1
        );
    }
    println!(
        "\nShape checks: (1) with the calibrated tau the mean error is within the \
         paper's ~10-30% band; (2) with a mis-tuned tau the error grows most in \
         the generation-heavy scenarios — the paper's OP4 pathology."
    );
    println!("wrote {}/fig11_OP*.csv", dir.display());
    println!("\n[bench] 8 panels in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
