//! D3 good fixture: total orders only — `total_cmp` for raw floats, and
//! the canonical `PartialOrd`-delegates-to-`Ord` impl (which the lint
//! recognizes and exempts).
use std::cmp::Ordering;

pub fn sort_desc(xs: &mut [f64]) {
    xs.sort_by(|a, b| b.total_cmp(a));
}

#[derive(PartialEq, Eq)]
pub struct Bits(pub u64);

impl Ord for Bits {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.cmp(&other.0)
    }
}

impl PartialOrd for Bits {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
