//! D3 bad fixture: raw-float `partial_cmp` sort — the NaN-panic class.
pub fn sort_desc(xs: &mut [f64]) {
    xs.sort_by(|a, b| b.partial_cmp(a).unwrap());
}

pub struct Row {
    pub score: f64,
}

pub fn rank(rows: &mut [Row]) {
    rows.sort_by_key(|r| (r.score * 1000.0) as i64);
}
