//! D4 bad fixture: hash-derived entropy outside `util::rng`.
use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

pub fn jitter(seed: &str) -> u64 {
    let mut h = DefaultHasher::new();
    seed.hash(&mut h);
    h.finish()
}
