//! D4 good fixture: consumers draw from the seeded stream, never from
//! ambient or hash-derived entropy.
use crate::util::rng::Rng;

pub fn jitter(rng: &mut Rng) -> u64 {
    rng.next_u64()
}
