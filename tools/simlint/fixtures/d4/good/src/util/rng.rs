//! D4 good fixture: `util/rng.rs` is the one home for randomness — a
//! seed-deterministic splitmix64 stream, no ambient entropy.
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed | 1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}
