#![allow(dead_code)]
//! D6 bad fixture: a blanket inner allow, plus a stale
//! `#[allow(clippy::too_many_arguments)]` on a two-parameter fn.

#[allow(clippy::too_many_arguments)]
pub fn combine(a: u32, b: u32) -> u32 {
    a + b
}
