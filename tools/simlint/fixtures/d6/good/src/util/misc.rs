//! D6 good fixture: the suppression is live — clippy's
//! `too_many_arguments` fires at 8+ parameters and this fn has 8.
#[allow(clippy::too_many_arguments)]
pub fn combine(a: u32, b: u32, c: u32, d: u32, e: u32, f: u32, g: u32, h: u32) -> u32 {
    a + b + c + d + e + f + g + h
}
