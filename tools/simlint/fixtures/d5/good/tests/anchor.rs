//! The rule-D5 anchor inventory for the good fixture: every gate field is
//! either toggled directly or reached through a named constructor.

#[test]
fn pruned_plan_equals_brute_force() {
    let brute = PruneConfig::none();
    let pruned = PruneConfig::all();
    let _ = (brute, pruned);
}

#[test]
fn front_cache_preserves_outputs() {
    for fast in [false, true] {
        let params = SimParams { front_cache: fast, ..SimParams::default() };
        let _ = params;
    }
}
