//! D5 good fixture: gate fields anchored via a named non-default
//! constructor that the test inventory references (`PruneConfig::none()`).
#[derive(Debug, Clone, Copy)]
pub struct PruneConfig {
    pub zero_filter: bool,
}

impl PruneConfig {
    pub fn all() -> Self {
        PruneConfig { zero_filter: true }
    }

    pub fn none() -> Self {
        PruneConfig { zero_filter: false }
    }
}
