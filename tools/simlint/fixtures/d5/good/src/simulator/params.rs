//! D5 good fixture: one gate anchored by a direct toggle in a test, one
//! diagnostics-only flag carrying a reasoned allow directive.
#[derive(Debug, Clone, Copy)]
pub struct SimParams {
    pub front_cache: bool,
    // simlint: allow(D5, diagnostics-only toggle; output equivalence is not defined for it)
    pub trace_events: bool,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams { front_cache: true, trace_events: false }
    }
}
