//! D5 bad fixture: a gate field with no on/off equivalence-test anchor —
//! there is no tests tree and no `#[cfg(test)]` module referencing it.
#[derive(Debug, Clone, Copy)]
pub struct PruneConfig {
    pub zero_filter: bool,
}

impl PruneConfig {
    pub fn all() -> Self {
        PruneConfig { zero_filter: true }
    }
}
