//! Rule-D5 anchors for the d2 good fixture: the fixture `Profiler` is a
//! gate struct, so its `enabled` gate needs the same on/off constructor
//! anchor the real tree has.

#[test]
fn profiled_sweep_matches_unprofiled() {
    let on = Profiler::on();
    let off = Profiler::off();
    let _ = (on, off);
}
