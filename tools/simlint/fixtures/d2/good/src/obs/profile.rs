//! D2 good fixture: obs/profile.rs is the one module besides
//! util/walltime.rs allowed to *hold* a wall-clock type — spans store
//! stopwatch-issued `Instant`s, and every read goes through the sanctioned
//! `stopwatch()` (a bare `Instant::now()` here would still be flagged).
use std::time::Instant;

use crate::util::walltime::stopwatch;

pub struct Profiler {
    pub enabled: bool,
    t0: Option<Instant>,
}

impl Profiler {
    pub fn on() -> Profiler {
        Profiler { enabled: true, t0: Some(stopwatch()) }
    }

    pub fn off() -> Profiler {
        Profiler { enabled: false, t0: None }
    }

    pub fn elapsed_s(&self) -> f64 {
        match self.t0 {
            Some(t0) => t0.elapsed().as_secs_f64(),
            None => 0.0,
        }
    }
}
