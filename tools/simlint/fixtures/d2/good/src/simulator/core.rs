//! D2 good fixture: simulated time flows from the event clock.
pub struct Clock {
    now: f64,
}

impl Clock {
    pub fn new() -> Self {
        Clock { now: 0.0 }
    }

    pub fn advance(&mut self, dt: f64) {
        self.now += dt;
    }

    pub fn now(&self) -> f64 {
        self.now
    }
}

impl Default for Clock {
    fn default() -> Self {
        Clock::new()
    }
}
