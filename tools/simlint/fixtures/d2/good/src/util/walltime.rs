//! D2 good fixture: util/walltime.rs is the one sanctioned stopwatch —
//! harness self-timing lives here and nowhere else.
use std::time::Instant;

pub fn stopwatch() -> Instant {
    Instant::now()
}
