//! D2 bad fixture: a wall-clock type in the observability plane. Only
//! `obs/profile.rs` may hold stopwatch-issued `Instant`s; counters and
//! gauges must stay clock-free so snapshots are deterministic.
use std::time::SystemTime;

pub struct Registry {
    started: SystemTime,
    count: u64,
}

impl Registry {
    pub fn bump(&mut self) {
        self.count += 1;
    }

    pub fn age(&self) -> SystemTime {
        self.started
    }
}
