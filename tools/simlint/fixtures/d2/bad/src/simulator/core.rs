//! D2 bad fixture: wall-clock reads inside simulation code.
use std::time::Instant;

pub fn step_duration() -> f64 {
    let t0 = Instant::now();
    let mut acc = 0.0_f64;
    for i in 0..1000 {
        acc += f64::from(i);
    }
    let _ = acc;
    t0.elapsed().as_secs_f64()
}
