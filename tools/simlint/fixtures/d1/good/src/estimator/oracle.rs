//! D1 good fixture: the escape hatch — a sharded memo whose maps are only
//! ever keyed into, never iterated, carries a reasoned allow directive.
use std::sync::Mutex;

// simlint: allow(D1, sharded memo: keyed lookups only, never iterated)
use std::collections::HashMap;

// simlint: allow(D1, sharded memo shard type; keyed lookups only)
pub type Memo = Vec<Mutex<HashMap<(u8, u32), f64>>>;

pub fn lookup(memo: &Memo, key: (u8, u32)) -> Option<f64> {
    let shard = (key.0 as usize) % memo.len();
    memo[shard].lock().unwrap().get(&key).copied()
}
