//! D1 good fixture: ordered maps keep iteration order out of hasher state.
use std::collections::BTreeMap;

pub fn line_groups(xs: &[(u32, f64)]) -> BTreeMap<u32, Vec<f64>> {
    let mut by_key: BTreeMap<u32, Vec<f64>> = BTreeMap::new();
    for (k, v) in xs {
        by_key.entry(*k).or_default().push(*v);
    }
    by_key
}
