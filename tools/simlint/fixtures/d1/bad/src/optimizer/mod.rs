//! D1 bad fixture: unordered maps in an ordering-sensitive module.
use std::collections::HashMap;

pub fn line_groups(xs: &[(u32, f64)]) -> HashMap<u32, Vec<f64>> {
    let mut by_key: HashMap<u32, Vec<f64>> = HashMap::new();
    for (k, v) in xs {
        by_key.entry(*k).or_default().push(*v);
    }
    by_key
}
