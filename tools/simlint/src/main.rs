//! CLI for the determinism lint. Default invocation (from the workspace
//! root, as CI runs it):
//!
//! ```text
//! cargo run -q -p simlint --
//! ```
//!
//! lints `rust/src` against rules D1–D6 with `rust/tests` as the test
//! inventory for rule D5. Exit codes: 0 clean, 1 findings, 2 usage/IO
//! error. `--src`/`--tests` override the roots (used by the fixture suite
//! and by the CI step that asserts each bad fixture trips).

use std::path::PathBuf;
use std::process::ExitCode;

use simlint::lint_tree;

fn main() -> ExitCode {
    let mut src = PathBuf::from("rust/src");
    let mut tests: Option<PathBuf> = Some(PathBuf::from("rust/tests"));
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--src" => match args.next() {
                Some(v) => src = PathBuf::from(v),
                None => return usage("--src needs a path"),
            },
            "--tests" => match args.next() {
                Some(v) => tests = Some(PathBuf::from(v)),
                None => return usage("--tests needs a path"),
            },
            "--no-tests" => tests = None,
            "--help" | "-h" => {
                print_usage();
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }

    if !src.is_dir() {
        eprintln!("simlint: source root `{}` is not a directory", src.display());
        return ExitCode::from(2);
    }
    // A missing tests root is fine (fixture trees without one): D5 then
    // simply has an empty inventory.
    let tests = tests.filter(|t| t.is_dir());

    let report = match lint_tree(&src, tests.as_deref()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("simlint: scan failed: {e}");
            return ExitCode::from(2);
        }
    };

    for g in &report.gates {
        let verdict = if g.anchored {
            format!("anchored ({})", g.how)
        } else {
            "UNANCHORED".to_string()
        };
        println!(
            "simlint: gate {}::{} ({}:{}) — {}",
            g.struct_name, g.field, g.file, g.line, verdict
        );
    }

    if report.findings.is_empty() {
        println!("simlint: {} clean (rules D1–D6)", src.display());
        return ExitCode::SUCCESS;
    }
    for f in &report.findings {
        println!("{f}");
    }
    println!(
        "simlint: {} finding(s) in {} — fix, or annotate with `// simlint: allow(Dx, reason)`",
        report.findings.len(),
        src.display()
    );
    ExitCode::FAILURE
}

fn print_usage() {
    println!(
        "usage: simlint [--src DIR] [--tests DIR | --no-tests]\n\
         \n\
         Lints DIR (default rust/src) against the determinism rules D1–D6;\n\
         the tests DIR (default rust/tests) is the rule-D5 anchor inventory.\n\
         Exit codes: 0 clean, 1 findings, 2 usage/IO error."
    );
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("simlint: {msg}");
    print_usage();
    ExitCode::from(2)
}
