//! `simlint` — the repo's determinism & bit-exactness static-analysis pass.
//!
//! BestServe's strongest guarantee is that rankings, `PlanReport`s and
//! validation rows are byte-identical across `--threads`, prune flags and
//! fast-path gates. The *dynamic* side of that contract lives in the
//! equivalence tests (`prop_pruned_plan_equals_brute_force`, the
//! `fast_paths_preserve_*` anchors, the thread-invariance suites); this
//! crate is the *static* side: a dependency-light token scan over
//! `rust/src` that proves the absence of whole nondeterminism classes
//! instead of sampling for their symptoms.
//!
//! # Rule catalog
//!
//! * **D1** — no `HashMap`/`HashSet` in the ordering-sensitive modules
//!   (`simulator`, `estimator`, `optimizer`, `planner`, `report`,
//!   `validation`): unordered iteration is how hasher state leaks into
//!   output bytes. Use `BTreeMap`/`BTreeSet` or a sorted drain; genuinely
//!   keyed-only cache internals (the sharded oracle memo) take a reasoned
//!   allow directive.
//! * **D2** — no wall-clock reads in simulation/estimation code
//!   (`Instant`, `SystemTime`): simulated time flows from the event clock
//!   (`simulator::core::Clock`). `Instant::now`/`SystemTime::now` are
//!   banned *everywhere* in the tree except `util/walltime.rs`, the one
//!   sanctioned stopwatch for self-timing harnesses. `obs/profile.rs`
//!   (the sweep profiler) may *hold* stopwatch-issued `Instant`s, but the
//!   `::now` calls stay banned there too — reads go through the stopwatch.
//! * **D3** — no `partial_cmp` sorts on raw floats (the NaN-panic /
//!   partial-order class PR 1 fixed must stay fixed): use `total_cmp` or
//!   `util::stats::rank_desc`. The canonical `PartialOrd`-delegates-to-
//!   `Ord` impl (`Some(self.cmp(other))`) is recognized and exempt.
//!   `sort_by_key` with a float-derived key is flagged by heuristic.
//! * **D4** — all randomness through `util::rng`: no `rand` crate, no
//!   hash-derived entropy (`RandomState`, `DefaultHasher`), no
//!   `thread_rng`/`from_entropy`-style ambient seeding.
//! * **D5** — every gate field of the gate structs (`PruneConfig`,
//!   `GoodputConfig`, `SimParams`) must be cross-referenced by the test
//!   inventory: either toggled directly in a test (`front_cache: fast`),
//!   or set by a named non-`default` constructor some test calls
//!   (`PruneConfig::none()`). A new fast path therefore cannot land
//!   ungated or unanchored.
//! * **D6** — stale suppressions: `#[allow(clippy::too_many_arguments)]`
//!   on a fn with ≤ 7 parameters, blanket `#![allow(...)]` inner
//!   attributes, and `simlint: allow` directives that suppress nothing.
//!
//! # The escape hatch
//!
//! A finding is suppressed by a reasoned directive on the same line or the
//! line directly above it:
//!
//! ```text
//! // simlint: allow(D1, sharded memo; keyed lookups only, never iterated)
//! use std::collections::HashMap;
//! ```
//!
//! The reason is mandatory (a directive without one is a **D0** finding),
//! and a directive that suppresses nothing is itself a D6 finding — the
//! allowlist cannot rot silently.
//!
//! # What this is (and is not)
//!
//! The scanner strips comments and string/char literals before matching
//! (directives are read from the raw text), so prose never trips a rule.
//! It is a token scan, not a type checker: rules are written to be
//! conservative on this repo's idioms, `clippy.toml` mirrors D2/D4 where
//! clippy can express them, and the equivalence tests remain the ground
//! truth the lint merely hardens.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Modules where unordered-map iteration can reach output bytes (rule D1).
const D1_MODULES: &[&str] =
    &["simulator", "estimator", "optimizer", "planner", "report", "validation"];

/// Modules that constitute simulation/estimation code (rule D2): any
/// wall-clock *type* is suspect here, not just `::now` calls.
const D2_MODULES: &[&str] =
    &["simulator", "estimator", "obs", "optimizer", "planner", "testbed", "validation"];

/// The structs whose `bool` fields gate output-preserving cuts (rule D5).
/// Extend this list when a new gate struct is introduced (see the
/// add-a-lint-rule recipe in ROADMAP.md).
const GATE_STRUCTS: &[&str] =
    &["PruneConfig", "GoodputConfig", "SimParams", "Profiler", "TestbedConfig"];

/// The one file allowed to read the wall clock (rule D2).
const WALLCLOCK_HOME: &str = "util/walltime.rs";

/// The one other file allowed to *hold* a wall-clock type (rule D2): the
/// sweep profiler stores stopwatch-issued `Instant`s for its spans.
/// `Instant::now`/`SystemTime::now` remain banned there — every read goes
/// through `util::walltime::stopwatch()`.
const PROFILE_HOME: &str = "obs/profile.rs";

/// The one module allowed to implement/own randomness (rule D4).
const RNG_HOME: &str = "util/rng.rs";

/// Tokens rule D4 bans outside [`RNG_HOME`].
const D4_TOKENS: &[&str] = &[
    "thread_rng",
    "ThreadRng",
    "StdRng",
    "SmallRng",
    "RandomState",
    "DefaultHasher",
    "from_entropy",
    "getrandom",
    "fastrand",
];

/// A lint rule identifier. `D0` is reserved for malformed directives (a
/// broken escape hatch must fail loudly, not silently allow nothing).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Rule {
    D0,
    D1,
    D2,
    D3,
    D4,
    D5,
    D6,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::D0 => "D0",
            Rule::D1 => "D1",
            Rule::D2 => "D2",
            Rule::D3 => "D3",
            Rule::D4 => "D4",
            Rule::D5 => "D5",
            Rule::D6 => "D6",
        }
    }

    fn parse(s: &str) -> Option<Rule> {
        match s.trim() {
            "D0" => Some(Rule::D0),
            "D1" => Some(Rule::D1),
            "D2" => Some(Rule::D2),
            "D3" => Some(Rule::D3),
            "D4" => Some(Rule::D4),
            "D5" => Some(Rule::D5),
            "D6" => Some(Rule::D6),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One violation. Ordered by (file, line, rule) so reports are
/// deterministic regardless of scan order.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    /// Path relative to the linted source root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// One gate field discovered by rule D5, with its anchoring verdict.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GateField {
    pub struct_name: String,
    pub field: String,
    /// Defining file (relative to the source root) and 1-based line.
    pub file: String,
    pub line: usize,
    /// Whether the test inventory exercises this gate.
    pub anchored: bool,
    /// Human-readable explanation of the anchor (empty when unanchored).
    pub how: String,
}

/// Full lint output: the (directive-filtered) findings plus the D5 gate
/// inventory, so callers can assert "every gate is anchored" positively
/// rather than only by absence of findings.
#[derive(Debug, Clone)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub gates: Vec<GateField>,
}

// ------------------------------------------------------------- directives --

#[derive(Debug)]
struct Directive {
    rule: Rule,
    /// 1-based line the directive comment sits on. It suppresses findings
    /// on this line and the line directly below.
    line: usize,
    used: bool,
}

/// Parse `simlint: allow(Dx, reason)` directives out of the raw (unstripped)
/// text; malformed directives become D0 findings.
fn parse_directives(rel: &str, raw: &str, findings: &mut Vec<Finding>) -> Vec<Directive> {
    let mut out = Vec::new();
    for (i, line) in raw.lines().enumerate() {
        let Some(pos) = line.find("simlint:") else { continue };
        let ln = i + 1;
        let rest = line[pos + "simlint:".len()..].trim_start();
        let mut malformed = |why: &str| {
            findings.push(Finding {
                file: rel.to_string(),
                line: ln,
                rule: Rule::D0,
                message: format!("malformed simlint directive ({why}); \
                     expected `simlint: allow(D<n>, reason)`"),
            });
        };
        let Some(inner) = rest.strip_prefix("allow(") else {
            malformed("not an allow(...)");
            continue;
        };
        let Some(close) = inner.rfind(')') else {
            malformed("missing closing parenthesis");
            continue;
        };
        let body = &inner[..close];
        let Some((rule_s, reason)) = body.split_once(',') else {
            malformed("missing the mandatory reason");
            continue;
        };
        let Some(rule) = Rule::parse(rule_s) else {
            malformed("unknown rule");
            continue;
        };
        if reason.trim().is_empty() {
            malformed("empty reason");
            continue;
        }
        out.push(Directive { rule, line: ln, used: false });
    }
    out
}

// ---------------------------------------------------------------- scanner --

/// Replace comment bodies and string/char-literal contents with spaces,
/// preserving every newline (so line numbers survive), so token rules never
/// fire inside prose or data. Handles nested block comments, raw strings
/// (`r"…"`, `r#"…"#`), escapes, and tells lifetimes (`'a`) from char
/// literals (`'a'`).
pub fn strip_code(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out: Vec<char> = Vec::with_capacity(n);
    let mut i = 0;
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    let is_ident = |c: char| c.is_alphanumeric() || c == '_';
    while i < n {
        let c = b[i];
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment (Rust block comments nest).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            out.push(' ');
            out.push(' ');
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"…" / r#"…"# (the repo has no byte-raw `br` strings).
        if c == 'r' && (i == 0 || !is_ident(b[i - 1])) {
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                // Blank from the opening r to the closing quote+hashes.
                for &c in &b[i..=j] {
                    out.push(blank(c));
                }
                i = j + 1;
                'raw: while i < n {
                    if b[i] == '"' {
                        let mut m = 0usize;
                        while m < hashes && i + 1 + m < n && b[i + 1 + m] == '#' {
                            m += 1;
                        }
                        if m == hashes {
                            for &c in &b[i..=(i + hashes)] {
                                out.push(blank(c));
                            }
                            i += hashes + 1;
                            break 'raw;
                        }
                    }
                    out.push(blank(b[i]));
                    i += 1;
                }
                continue;
            }
        }
        // Normal (or byte) string literal.
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    out.push(' ');
                    out.push(blank(b[i + 1]));
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                }
                out.push(blank(b[i]));
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // Escaped char literal: consume to the closing quote.
                out.push(' ');
                i += 1;
                while i < n && b[i] != '\'' {
                    if b[i] == '\\' && i + 1 < n {
                        out.push(' ');
                        out.push(blank(b[i + 1]));
                        i += 2;
                        continue;
                    }
                    out.push(blank(b[i]));
                    i += 1;
                }
                if i < n {
                    out.push(' ');
                    i += 1;
                }
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                // Plain char literal 'x'.
                out.push(' ');
                out.push(' ');
                out.push(' ');
                i += 3;
                continue;
            }
            // Lifetime: keep the tick, scan on.
            out.push('\'');
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out.into_iter().collect()
}

fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte offsets of `word` in `line` occurring as a whole identifier.
fn ident_positions(line: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let bytes = line.as_bytes();
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let p = start + pos;
        let end = p + word.len();
        let before_ok = p == 0 || !is_ident_byte(bytes[p - 1]);
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            out.push(p);
        }
        start = p + word.len();
    }
    out
}

fn has_ident(line: &str, word: &str) -> bool {
    !ident_positions(line, word).is_empty()
}

/// `field :` (struct-literal or struct-definition assignment), rejecting
/// `field::path` uses.
fn has_field_assign(line: &str, field: &str) -> bool {
    for p in ident_positions(line, field) {
        let rest = line[p + field.len()..].trim_start();
        if rest.starts_with(':') && !rest.starts_with("::") {
            return true;
        }
    }
    false
}

/// `root::` path use (e.g. the `rand` crate), as opposed to a bare ident.
fn has_path_root(line: &str, root: &str) -> bool {
    for p in ident_positions(line, root) {
        if line[p + root.len()..].trim_start().starts_with("::") {
            return true;
        }
    }
    false
}

/// First path component with any `.rs` suffix stripped: the top-level
/// module a file belongs to (`optimizer/mod.rs` → `optimizer`).
fn top_module(rel: &str) -> &str {
    let first = rel.split('/').next().unwrap_or(rel);
    first.strip_suffix(".rs").unwrap_or(first)
}

/// Count the parameters of the fn whose signature starts in `sig` (text
/// beginning at the line containing the `fn` keyword). `None` when the
/// signature cannot be delimited (never flag what we cannot parse).
/// `self` counts as a parameter, which makes the D6 staleness check
/// conservative (clippy's threshold is 8+ either way).
fn count_fn_params(sig: &str) -> Option<usize> {
    let cs: Vec<char> = sig.chars().collect();
    let n = cs.len();
    let is_ident = |c: char| c.is_alphanumeric() || c == '_';
    // Locate the `fn` keyword.
    let mut fn_pos = None;
    let mut k = 0;
    while k + 1 < n {
        if cs[k] == 'f'
            && cs[k + 1] == 'n'
            && (k == 0 || !is_ident(cs[k - 1]))
            && (k + 2 >= n || !is_ident(cs[k + 2]))
        {
            fn_pos = Some(k);
            break;
        }
        k += 1;
    }
    let mut i = fn_pos? + 2;
    // Find the parameter list's opening paren, skipping generic params
    // (which may themselves contain parens, e.g. `F: Fn(u32) -> u32`).
    let mut angle: i32 = 0;
    let mut prev = ' ';
    while i < n {
        let c = cs[i];
        match c {
            '<' => angle += 1,
            '>' if prev != '-' => angle -= 1,
            '(' if angle <= 0 => break,
            _ => {}
        }
        prev = c;
        i += 1;
    }
    if i >= n {
        return None;
    }
    // Count top-level commas inside the list; a trailing comma (rustfmt's
    // vertical layout) separates nothing.
    let mut depth: i32 = 1;
    let mut commas = 0usize;
    let mut any = false;
    let mut last = ' ';
    angle = 0;
    prev = ' ';
    i += 1;
    while i < n && depth > 0 {
        let c = cs[i];
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            '<' => angle += 1,
            '>' if prev != '-' => angle = (angle - 1).max(0),
            ',' if depth == 1 && angle == 0 => commas += 1,
            _ => {}
        }
        if depth > 0 && !c.is_whitespace() {
            any = true;
            last = c;
        }
        prev = c;
        i += 1;
    }
    if depth != 0 {
        return None;
    }
    if !any {
        return Some(0);
    }
    Some(if last == ',' { commas } else { commas + 1 })
}

// -------------------------------------------------------- per-file rules --

struct SourceFile {
    rel: String,
    raw: String,
    code: String,
}

impl SourceFile {
    fn code_lines(&self) -> Vec<&str> {
        self.code.lines().collect()
    }

    /// 0-based index of the `#[cfg(test)]` boundary (convention in this
    /// repo: the tests module closes the file), or `len` when absent.
    fn test_region_start(&self) -> usize {
        let lines = self.code_lines();
        lines
            .iter()
            .position(|l| l.contains("#[cfg(test)]"))
            .unwrap_or(lines.len())
    }
}

fn push(out: &mut Vec<Finding>, rel: &str, line: usize, rule: Rule, message: String) {
    out.push(Finding { file: rel.to_string(), line, rule, message });
}

/// Rules D1–D4 and the per-file half of D6.
fn file_findings(sf: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    let module = top_module(&sf.rel);
    let d1 = D1_MODULES.contains(&module);
    let d2 = D2_MODULES.contains(&module);
    let rng_home = sf.rel == RNG_HOME;
    let wallclock_home = sf.rel == WALLCLOCK_HOME;
    let profile_home = sf.rel == PROFILE_HOME;
    let lines = sf.code_lines();

    for (i, line) in lines.iter().enumerate() {
        let ln = i + 1;

        if d1 {
            for w in ["HashMap", "HashSet"] {
                if has_ident(line, w) {
                    push(
                        &mut out,
                        &sf.rel,
                        ln,
                        Rule::D1,
                        format!(
                            "`{w}` in ordering-sensitive module `{module}` — iteration order \
                             is hasher state; use BTreeMap/BTreeSet or a sorted drain"
                        ),
                    );
                    break;
                }
            }
        }

        if d2 && !profile_home {
            for w in ["Instant", "SystemTime"] {
                if has_ident(line, w) {
                    push(
                        &mut out,
                        &sf.rel,
                        ln,
                        Rule::D2,
                        format!(
                            "wall-clock type `{w}` in simulation/estimation module `{module}` — \
                             simulated time must flow from the event clock"
                        ),
                    );
                    break;
                }
            }
        } else if !wallclock_home
            && (line.contains("Instant::now") || line.contains("SystemTime::now"))
        {
            push(
                &mut out,
                &sf.rel,
                ln,
                Rule::D2,
                "wall-clock read outside util/walltime.rs — use \
                 `util::walltime::stopwatch()` for harness timing"
                    .to_string(),
            );
        }

        if has_ident(line, "partial_cmp") {
            // The canonical PartialOrd-delegates-to-Ord impl is the
            // approved pattern; everything else risks the NaN class.
            let window_end = (i + 3).min(lines.len());
            let canonical = lines[i..window_end].iter().any(|l| l.contains("self.cmp(other)"));
            if !canonical {
                push(
                    &mut out,
                    &sf.rel,
                    ln,
                    Rule::D3,
                    "`partial_cmp` on floats is a partial order (NaN panics / unstable \
                     rankings) — use `total_cmp` or `util::stats::rank_desc`"
                        .to_string(),
                );
            }
        }
        if has_ident(line, "sort_by_key")
            && (line.contains("f64")
                || line.contains("f32")
                || line.contains(" as i")
                || line.contains(" as u"))
        {
            push(
                &mut out,
                &sf.rel,
                ln,
                Rule::D3,
                "`sort_by_key` over a float-derived key collapses distinct floats — \
                 sort with `total_cmp` on the float itself"
                    .to_string(),
            );
        }

        if !rng_home {
            if has_path_root(line, "rand") {
                push(
                    &mut out,
                    &sf.rel,
                    ln,
                    Rule::D4,
                    "the `rand` crate is banned — all randomness flows through `util::rng` \
                     so streams are seed-deterministic"
                        .to_string(),
                );
            }
            for w in D4_TOKENS {
                if has_ident(line, w) {
                    push(
                        &mut out,
                        &sf.rel,
                        ln,
                        Rule::D4,
                        format!(
                            "`{w}` is hash-derived/ambient entropy — all randomness flows \
                             through `util::rng`"
                        ),
                    );
                    break;
                }
            }
        }

        // D6(a): stale #[allow(clippy::too_many_arguments)].
        if line.contains("#[allow") && line.contains("too_many_arguments") {
            let horizon = (i + 16).min(lines.len());
            if let Some(j) = (i + 1..horizon).find(|&j| has_ident(lines[j], "fn")) {
                let sig_end = (j + 60).min(lines.len());
                let sig = lines[j..sig_end].join("\n");
                if let Some(nargs) = count_fn_params(&sig) {
                    if nargs <= 7 {
                        push(
                            &mut out,
                            &sf.rel,
                            ln,
                            Rule::D6,
                            format!(
                                "stale `#[allow(clippy::too_many_arguments)]`: the fn takes \
                                 {nargs} parameter(s), clippy fires at 8+"
                            ),
                        );
                    }
                }
            }
        }
        // D6(b): blanket inner allows hide violations file-wide.
        if line.trim_start().starts_with("#![allow(") {
            push(
                &mut out,
                &sf.rel,
                ln,
                Rule::D6,
                "blanket `#![allow(...)]` — scope the suppression to the item it \
                 justifies"
                    .to_string(),
            );
        }
    }
    out
}

// -------------------------------------------------------------- rule D5 ---

struct StructDef {
    name: String,
    /// 0-based line range [start, end] of the definition, inclusive.
    start: usize,
    end: usize,
    /// (field, 0-based line) of each `pub <field>: bool`.
    bool_fields: Vec<(String, usize)>,
}

/// Extract a gate struct's definition from stripped lines.
fn find_struct(lines: &[&str], name: &str) -> Option<StructDef> {
    let start = lines
        .iter()
        .position(|l| has_ident(l, "struct") && has_ident(l, name))?;
    // Brace-match from the first `{` at or after the header line.
    let mut depth = 0i32;
    let mut opened = false;
    let mut end = start;
    'outer: for (j, line) in lines.iter().enumerate().skip(start) {
        for c in line.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => {
                    depth -= 1;
                    if opened && depth == 0 {
                        end = j;
                        break 'outer;
                    }
                }
                ';' if !opened => return None, // unit/tuple struct
                _ => {}
            }
        }
        end = j;
    }
    let mut bool_fields = Vec::new();
    for (j, line) in lines.iter().enumerate().take(end + 1).skip(start) {
        let t = line.trim();
        let Some(rest) = t.strip_prefix("pub ") else { continue };
        let Some(colon) = rest.find(':') else { continue };
        let field = rest[..colon].trim();
        if field.is_empty() || !field.chars().all(|c| c.is_alphanumeric() || c == '_') {
            continue;
        }
        let ty = rest[colon + 1..].trim().trim_end_matches(',').trim();
        if ty == "bool" {
            bool_fields.push((field.to_string(), j));
        }
    }
    Some(StructDef { name: name.to_string(), start, end, bool_fields })
}

/// Name of the nearest enclosing fn above `line_idx` (simple upward scan —
/// closures have no `fn` keyword, so this lands on the real item).
fn enclosing_fn(lines: &[&str], line_idx: usize) -> Option<String> {
    for j in (0..=line_idx).rev() {
        let line = lines[j];
        if let Some(p) = ident_positions(line, "fn").first().copied() {
            let rest = line[p + 2..].trim_start();
            let name: String = rest
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
    }
    None
}

/// Rule D5: parse the gate structs, then require each bool gate field to be
/// exercised by the test inventory — directly (`field: <expr>` in a test)
/// or via a non-`default` constructor that sets it (`Struct::ctor(`
/// referenced in a test).
fn gate_findings(
    sources: &[SourceFile],
    inventory: &[String],
    findings: &mut Vec<Finding>,
) -> Vec<GateField> {
    let mut gates = Vec::new();
    for sf in sources {
        let lines = sf.code_lines();
        let test_start = sf.test_region_start();
        for &gate in GATE_STRUCTS {
            let Some(def) = find_struct(&lines, gate) else { continue };
            for (field, field_line) in &def.bool_fields {
                // Constructors in the defining file that set this field
                // (outside the struct def, outside the tests module).
                let mut ctors: Vec<String> = Vec::new();
                for (j, line) in lines.iter().enumerate().take(test_start) {
                    if j >= def.start && j <= def.end {
                        continue;
                    }
                    if has_field_assign(line, field) {
                        if let Some(f) = enclosing_fn(&lines, j) {
                            if f != "default" && !ctors.contains(&f) {
                                ctors.push(f);
                            }
                        }
                    }
                }
                let direct = inventory
                    .iter()
                    .any(|text| text.lines().any(|l| has_field_assign(l, field)));
                let ctor_hit = ctors.iter().find(|c| {
                    let call = format!("{}::{}(", def.name, c);
                    inventory.iter().any(|text| text.contains(&call))
                });
                let (anchored, how) = if direct {
                    (true, "toggled directly in the test inventory".to_string())
                } else if let Some(c) = ctor_hit {
                    (true, format!("via {}::{}() referenced in tests", def.name, c))
                } else {
                    (false, String::new())
                };
                if !anchored {
                    push(
                        findings,
                        &sf.rel,
                        field_line + 1,
                        Rule::D5,
                        format!(
                            "gate field `{}::{}` has no on/off equivalence-test anchor — \
                             toggle it in a test, or reference a non-default constructor \
                             that sets it",
                            def.name, field
                        ),
                    );
                }
                gates.push(GateField {
                    struct_name: def.name.clone(),
                    field: field.clone(),
                    file: sf.rel.clone(),
                    line: field_line + 1,
                    anchored,
                    how,
                });
            }
        }
    }
    gates
}

// ------------------------------------------------------------ tree walk ---

fn collect_rs(root: &Path, rel: &str, out: &mut Vec<(String, PathBuf)>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(root)?.collect::<io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        let name = e.file_name().to_string_lossy().into_owned();
        let child_rel = if rel.is_empty() { name.clone() } else { format!("{rel}/{name}") };
        if path.is_dir() {
            collect_rs(&path, &child_rel, out)?;
        } else if name.ends_with(".rs") {
            out.push((child_rel, path));
        }
    }
    Ok(())
}

/// Lint a source tree. `src_root` is scanned recursively with all rules;
/// `tests_root` (plus the `#[cfg(test)]` tails of the source files) forms
/// the test inventory rule D5 greps for anchors.
pub fn lint_tree(src_root: &Path, tests_root: Option<&Path>) -> io::Result<LintReport> {
    let mut files = Vec::new();
    collect_rs(src_root, "", &mut files)?;

    let mut findings: Vec<Finding> = Vec::new();
    let mut sources: Vec<SourceFile> = Vec::with_capacity(files.len());
    for (rel, abs) in files {
        let raw = fs::read_to_string(&abs)?;
        let code = strip_code(&raw);
        sources.push(SourceFile { rel, raw, code });
    }

    // Test inventory: integration-test files + in-module test regions, all
    // stripped so prose cannot anchor a gate.
    let mut inventory: Vec<String> = Vec::new();
    if let Some(tr) = tests_root {
        let mut tfiles = Vec::new();
        collect_rs(tr, "", &mut tfiles)?;
        for (_, abs) in tfiles {
            inventory.push(strip_code(&fs::read_to_string(&abs)?));
        }
    }
    for sf in &sources {
        let lines = sf.code_lines();
        let start = sf.test_region_start();
        if start < lines.len() {
            inventory.push(lines[start..].join("\n"));
        }
    }

    // Per-file rules, then D5 across the tree.
    let mut raw_findings: Vec<Finding> = Vec::new();
    for sf in &sources {
        raw_findings.extend(file_findings(sf));
    }
    let gates = gate_findings(&sources, &inventory, &mut raw_findings);

    // Apply allow directives: a directive suppresses matching-rule findings
    // on its own line or the line directly below.
    for sf in &sources {
        let mut directives = parse_directives(&sf.rel, &sf.raw, &mut findings);
        raw_findings.retain(|f| {
            if f.file != sf.rel {
                return true;
            }
            for d in directives.iter_mut() {
                if d.rule == f.rule && (f.line == d.line || f.line == d.line + 1) {
                    d.used = true;
                    return false;
                }
            }
            true
        });
        for d in directives {
            if !d.used {
                push(
                    &mut findings,
                    &sf.rel,
                    d.line,
                    Rule::D6,
                    format!(
                        "unused `simlint: allow({})` directive — it suppresses nothing; \
                         remove it or move it onto the violating line",
                        d.rule
                    ),
                );
            }
        }
    }
    findings.extend(raw_findings);

    findings.sort();
    findings.dedup_by(|a, b| a.file == b.file && a.line == b.line && a.rule == b.rule);
    Ok(LintReport { findings, gates })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_removes_comments_strings_and_char_literals() {
        let src = "let x = \"HashMap\"; // HashMap\nlet c = 'H'; /* HashMap */ let l: &'a str = s;";
        let out = strip_code(src);
        assert!(!out.contains("HashMap"), "{out}");
        // Line structure survives.
        assert_eq!(out.lines().count(), src.lines().count());
        // Lifetimes survive (they are not char literals).
        assert!(out.contains("&'a str"));
    }

    #[test]
    fn strip_handles_raw_and_escaped_strings() {
        let src = "let a = r#\"Instant::now\"#; let b = \"\\\"SystemTime\\\"\";";
        let out = strip_code(src);
        assert!(!out.contains("Instant"));
        assert!(!out.contains("SystemTime"));
    }

    #[test]
    fn ident_matching_respects_word_boundaries() {
        assert!(has_ident("use std::collections::HashMap;", "HashMap"));
        assert!(!has_ident("let not_a_hash_map_token = 1;", "HashMap"));
        assert!(!has_ident("randomize()", "rand"));
        assert!(has_path_root("rand::random()", "rand"));
        assert!(!has_path_root("operand::x", "rand"));
        assert!(has_field_assign("    front_cache: fast,", "front_cache"));
        assert!(!has_field_assign("    FrontCache::new()", "front_cache"));
    }

    #[test]
    fn fn_param_counting_handles_generics_nested_types_and_trailing_commas() {
        assert_eq!(count_fn_params("fn f() {}"), Some(0));
        assert_eq!(count_fn_params("fn f(a: u32, b: u32) {}"), Some(2));
        assert_eq!(count_fn_params("fn f(m: &HashMap<(usize, u32), Arc<dyn X>>) {}"), Some(1));
        assert_eq!(
            count_fn_params("fn f<F: Fn(u32) -> u32>(x: F, run: impl FnMut(usize, u8) -> u8) {}"),
            Some(2)
        );
        let vertical = "pub fn g(\n    a: u32,\n    b: u32,\n    c: u32,\n) -> u32 {";
        assert_eq!(count_fn_params(vertical), Some(3));
    }

    #[test]
    fn malformed_directives_are_d0_findings() {
        let mut findings = Vec::new();
        let raw = "// simlint: allow(D1)\n// simlint: allow(D9, reason)\n// simlint: allow(D1, ok)\n";
        let ds = parse_directives("x.rs", raw, &mut findings);
        assert_eq!(ds.len(), 1, "only the well-formed directive parses");
        assert_eq!(findings.len(), 2);
        assert!(findings.iter().all(|f| f.rule == Rule::D0));
    }

    #[test]
    fn canonical_partial_ord_delegation_is_exempt() {
        let sf = SourceFile {
            rel: "util/order.rs".into(),
            raw: String::new(),
            code: strip_code(
                "impl PartialOrd for T {\n    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {\n        Some(self.cmp(other))\n    }\n}\n",
            ),
        };
        assert!(file_findings(&sf).is_empty());
    }
}
