//! Fixture-based self-tests for the determinism lint, plus the self-check
//! that the real tree (`rust/src`) is clean and every gate field is
//! anchored. These run under plain `cargo test -p simlint` — the fixtures
//! are data, never compiled.

use std::path::PathBuf;

use simlint::{lint_tree, LintReport, Rule};

fn crate_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn repo_root() -> PathBuf {
    crate_dir()
        .parent()
        .and_then(|p| p.parent())
        .expect("tools/simlint sits two levels below the repo root")
        .to_path_buf()
}

fn lint_fixture(rule: &str, kind: &str) -> LintReport {
    let root = crate_dir().join("fixtures").join(rule).join(kind);
    let src = root.join("src");
    let tests = root.join("tests");
    let tests = tests.is_dir().then_some(tests);
    lint_tree(&src, tests.as_deref())
        .unwrap_or_else(|e| panic!("scanning fixture {rule}/{kind} failed: {e}"))
}

const RULES: [(&str, Rule); 6] = [
    ("d1", Rule::D1),
    ("d2", Rule::D2),
    ("d3", Rule::D3),
    ("d4", Rule::D4),
    ("d5", Rule::D5),
    ("d6", Rule::D6),
];

#[test]
fn bad_fixtures_trip_exactly_their_rule() {
    for (name, rule) in RULES {
        let report = lint_fixture(name, "bad");
        assert!(
            !report.findings.is_empty(),
            "fixture {name}/bad should trip rule {rule:?} but linted clean"
        );
        for f in &report.findings {
            assert_eq!(
                f.rule, rule,
                "fixture {name}/bad tripped an unexpected rule: {f}"
            );
        }
    }
}

#[test]
fn good_fixtures_lint_clean() {
    for (name, _) in RULES {
        let report = lint_fixture(name, "good");
        assert!(
            report.findings.is_empty(),
            "fixture {name}/good should be clean, got:\n{}",
            report
                .findings
                .iter()
                .map(|f| f.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}

#[test]
fn rust_src_is_simlint_clean() {
    let src = repo_root().join("rust").join("src");
    let tests = repo_root().join("rust").join("tests");
    let report = lint_tree(&src, Some(&tests)).expect("scanning rust/src failed");
    assert!(
        report.findings.is_empty(),
        "rust/src must lint clean (fix, or annotate with a reasoned \
         `// simlint: allow(Dx, reason)`), got:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// The positive half of rule D5: the gate structs were actually discovered
/// (a silently-empty scan would make `rust_src_is_simlint_clean`
/// meaningless for D5) and every gate field has a test anchor.
#[test]
fn gate_fields_are_anchored_by_equivalence_tests() {
    let src = repo_root().join("rust").join("src");
    let tests = repo_root().join("rust").join("tests");
    let report = lint_tree(&src, Some(&tests)).expect("scanning rust/src failed");

    let expected = [
        ("PruneConfig", "zero_filter"),
        ("PruneConfig", "warm_start"),
        ("PruneConfig", "bound_dominance"),
        ("GoodputConfig", "workload_cache"),
        ("SimParams", "kv_transfer"),
        ("SimParams", "front_cache"),
        ("SimParams", "sim_trace"),
        ("SimParams", "failures"),
        ("Profiler", "enabled"),
        ("TestbedConfig", "kv_transfer"),
        ("TestbedConfig", "failures"),
    ];
    for (s, f) in expected {
        let gate = report
            .gates
            .iter()
            .find(|g| g.struct_name == s && g.field == f)
            .unwrap_or_else(|| panic!("gate {s}::{f} was not discovered by rule D5"));
        assert!(
            gate.anchored,
            "gate {s}::{f} ({}:{}) has no equivalence-test anchor",
            gate.file, gate.line
        );
    }
    for g in &report.gates {
        assert!(
            g.anchored,
            "gate {}::{} ({}:{}) has no equivalence-test anchor — add an on/off \
             equivalence test per the add-a-fast-path recipe",
            g.struct_name, g.field, g.file, g.line
        );
    }
}
